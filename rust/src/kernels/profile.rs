//! Kernel tuning profiles: versioned, persisted per-shape parameter tables
//! that drive the blocked kernels in this layer (`bdia tune`).
//!
//! A [`KernelProfile`] maps an operation key — op kind + problem dims +
//! thread count — to the tunable knobs of the corresponding kernel: k-panel
//! size `kc`, row-grain flop budget `grain_flop`, inner-loop chunk width
//! `unroll`, and whether `matmul_nt_w` may reuse a cached weight transpose
//! (`nt_cache`).
//!
//! **Any legal profile is bit-exact by construction.**  The knobs can only
//! move task-split boundaries (`grain_flop`), regroup the k loop into
//! panels without reordering it (`kc`), chunk *independent output elements*
//! at a fixed width (`unroll`), or reuse a bitwise-identical transpose
//! (`nt_cache`).  None of them can change the per-element reduction order,
//! so every output bit matches the default profile at every thread count —
//! `tests/profile_tuning.rs` proves this over randomized profiles.
//!
//! Profiles persist as versioned JSON (`{"bdia_profile": 1, ...}`) written
//! atomically (tmp file + rename) next to the checkpoint by `bdia tune`,
//! and load at session startup via `--tune-profile` /
//! `SessionBuilder::tune_profile`.  A corrupt or wrong-version file is
//! rejected with a clear error and the caller falls back to the default
//! profile, which reproduces today's constants bit-for-bit.

use crate::config::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Profile format version this build reads and writes.
pub const PROFILE_VERSION: usize = 1;

/// Which kernel an entry tunes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpKind {
    /// `matmul` / `linear` (`mm_bias`): key is (m, k, n).
    Matmul,
    /// `matmul_tn`: key is (m, k, n) as passed to the kernel.
    MatmulTn,
    /// `matmul_nt` / `matmul_nt_w`: key is (m, k, n) with `a` m×k, `b` n×k.
    MatmulNt,
    /// Per-head attention loops: key is (b·heads, tq·tk, dh).
    Attention,
}

impl OpKind {
    pub const ALL: [OpKind; 4] =
        [OpKind::Matmul, OpKind::MatmulTn, OpKind::MatmulNt, OpKind::Attention];

    pub fn name(self) -> &'static str {
        match self {
            OpKind::Matmul => "matmul",
            OpKind::MatmulTn => "matmul_tn",
            OpKind::MatmulNt => "matmul_nt",
            OpKind::Attention => "attention",
        }
    }

    pub fn parse(s: &str) -> Result<OpKind> {
        OpKind::ALL
            .into_iter()
            .find(|o| o.name() == s)
            .with_context(|| format!("unknown profile op kind '{s}'"))
    }
}

/// The tunable knobs of one kernel invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpParams {
    /// k-panel size for blocked reductions.  Panels regroup the k loop but
    /// never reorder it, so any `kc >= 1` yields identical bits.
    pub kc: usize,
    /// Row-grain flop budget: a pool task owns
    /// `(grain_flop / work_per_row).max(1)` rows.  Only moves task-split
    /// boundaries — row-partitioned kernels are split-independent.
    pub grain_flop: usize,
    /// Chunk width for inner loops over *independent output elements*
    /// (1, 2, 4, 8 or 16).  Never applied across a reduction, so each
    /// output element still receives exactly one update per k step.
    pub unroll: usize,
    /// Allow `matmul_nt_w` to reuse a cached transpose of a static weight
    /// (bitwise-identical to a fresh transpose).
    pub nt_cache: bool,
}

impl OpParams {
    /// Today's hard-coded constants, bit-for-bit: `KC = 64`,
    /// `GRAIN_FLOP = 1 << 14`, scalar inner loops, no transpose cache.
    pub const DEFAULT: OpParams =
        OpParams { kc: 64, grain_flop: 1 << 14, unroll: 1, nt_cache: false };

    pub fn validate(&self) -> Result<()> {
        ensure!(self.kc >= 1, "profile kc must be >= 1 (got {})", self.kc);
        ensure!(
            self.grain_flop >= 1,
            "profile grain_flop must be >= 1 (got {})",
            self.grain_flop
        );
        ensure!(
            matches!(self.unroll, 1 | 2 | 4 | 8 | 16),
            "profile unroll must be one of 1/2/4/8/16 (got {})",
            self.unroll
        );
        Ok(())
    }
}

impl Default for OpParams {
    fn default() -> Self {
        OpParams::DEFAULT
    }
}

/// What one profile entry is keyed by: op kind, problem dims, thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OpKey {
    pub op: OpKind,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// `pool::threads()` at lookup time — a profile tuned at 2 threads says
    /// nothing about 8, so entries only match their own thread count.
    pub threads: usize,
}

impl OpKey {
    /// Rough flop count, used to rank shapes by how much they matter.
    pub fn work(&self) -> usize {
        self.m.saturating_mul(self.k).saturating_mul(self.n)
    }
}

/// A versioned, serializable set of kernel parameters: per-shape entries
/// over a fallback [`OpParams`] for everything unlisted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelProfile {
    pub version: usize,
    /// Human-readable identity (surfaced by `bdia info` and `/stats`).
    pub id: String,
    /// Parameters for shapes without an entry.
    pub default_params: OpParams,
    pub entries: BTreeMap<OpKey, OpParams>,
}

impl Default for KernelProfile {
    /// Reproduces today's constants bit-for-bit for every op and shape.
    fn default() -> Self {
        KernelProfile {
            version: PROFILE_VERSION,
            id: "default".into(),
            default_params: OpParams::DEFAULT,
            entries: BTreeMap::new(),
        }
    }
}

impl KernelProfile {
    /// Parameters for one kernel invocation.
    pub fn params(&self, key: &OpKey) -> OpParams {
        self.entries.get(key).copied().unwrap_or(self.default_params)
    }

    /// True when every lookup would return [`OpParams::DEFAULT`] — the
    /// lock-free fast path in [`params_for`] keys off this.
    pub fn is_default(&self) -> bool {
        self.default_params == OpParams::DEFAULT && self.entries.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.version == PROFILE_VERSION,
            "unsupported profile version {} (this build reads version \
             {PROFILE_VERSION})",
            self.version
        );
        self.default_params.validate()?;
        for (key, p) in &self.entries {
            p.validate().with_context(|| {
                format!(
                    "entry {} m={} k={} n={} threads={}",
                    key.op.name(),
                    key.m,
                    key.k,
                    key.n,
                    key.threads
                )
            })?;
        }
        Ok(())
    }

    /// Canonical JSON rendering.  Entries iterate in `BTreeMap` order and
    /// every field prints in a fixed order, so save → load → save is
    /// byte-identical.
    pub fn to_json_string(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"bdia_profile\": {}, \"id\": \"{}\", \"default\": {}, \
             \"entries\": [",
            self.version,
            self.id.escape_default(),
            fmt_params(&self.default_params)
        );
        for (i, (key, p)) in self.entries.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(
                s,
                "{{\"op\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \
                 \"threads\": {}, \"params\": {}}}",
                key.op.name(),
                key.m,
                key.k,
                key.n,
                key.threads,
                fmt_params(p)
            );
        }
        s.push_str("]}");
        s
    }

    /// Parse + validate a profile document.  Corrupt JSON, a wrong
    /// `bdia_profile` version, missing fields, and illegal parameter
    /// values are all rejected with a clear error.
    pub fn from_json(s: &str) -> Result<Self> {
        let j = Json::parse(s).context("profile is not valid JSON")?;
        let version = j
            .get("bdia_profile")
            .context("no \"bdia_profile\" version field")?
            .as_usize()
            .context("\"bdia_profile\" must be an integer")?;
        ensure!(
            version == PROFILE_VERSION,
            "unsupported profile version {version} (this build reads \
             version {PROFILE_VERSION})"
        );
        let id = j.get("id")?.as_str().context("\"id\"")?.to_string();
        let default_params =
            parse_params(j.get("default")?).context("in \"default\"")?;
        let mut entries = BTreeMap::new();
        for (i, e) in j.get("entries")?.as_arr()?.iter().enumerate() {
            let parsed = parse_entry(e).with_context(|| format!("entry {i}"))?;
            entries.insert(parsed.0, parsed.1);
        }
        let profile = KernelProfile { version, id, default_params, entries };
        profile.validate()?;
        Ok(profile)
    }

    /// Atomically persist as canonical JSON: write a tmp sibling, fsync,
    /// rename over `path`, fsync the directory — a crash leaves either the
    /// old file or the new one, never a torn profile.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        let json = self.to_json_string();
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        {
            let mut f = fs::File::create(&tmp).with_context(|| {
                format!("creating profile tmp file {}", tmp.display())
            })?;
            f.write_all(json.as_bytes())
                .and_then(|()| f.sync_all())
                .with_context(|| format!("writing {}", tmp.display()))?;
        }
        fs::rename(&tmp, path).with_context(|| {
            format!("renaming {} -> {}", tmp.display(), path.display())
        })?;
        if let Some(dir) = path.parent() {
            if let Ok(d) = fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let s = fs::read_to_string(path).with_context(|| {
            format!("reading tune profile {}", path.display())
        })?;
        Self::from_json(&s)
            .with_context(|| format!("tune profile {}", path.display()))
    }
}

fn fmt_params(p: &OpParams) -> String {
    format!(
        "{{\"kc\": {}, \"grain_flop\": {}, \"unroll\": {}, \"nt_cache\": {}}}",
        p.kc, p.grain_flop, p.unroll, p.nt_cache
    )
}

fn usize_field(j: &Json, name: &str) -> Result<usize> {
    j.get(name)?.as_usize().with_context(|| format!("\"{name}\""))
}

fn parse_params(j: &Json) -> Result<OpParams> {
    Ok(OpParams {
        kc: usize_field(j, "kc")?,
        grain_flop: usize_field(j, "grain_flop")?,
        unroll: usize_field(j, "unroll")?,
        nt_cache: j.get("nt_cache")?.as_bool().context("\"nt_cache\"")?,
    })
}

fn parse_entry(j: &Json) -> Result<(OpKey, OpParams)> {
    let op = OpKind::parse(j.get("op")?.as_str().context("\"op\"")?)?;
    let key = OpKey {
        op,
        m: usize_field(j, "m")?,
        k: usize_field(j, "k")?,
        n: usize_field(j, "n")?,
        threads: usize_field(j, "threads")?,
    };
    let params = parse_params(j.get("params")?)?;
    Ok((key, params))
}

// ---------------------------------------------------------------------------
// Process-global active profile
// ---------------------------------------------------------------------------

struct Active {
    profile: Arc<KernelProfile>,
    source: Option<PathBuf>,
}

static ACTIVE: RwLock<Option<Active>> = RwLock::new(None);
/// Lock-free fast path: false means every lookup returns
/// [`OpParams::DEFAULT`], so the hot kernels skip the `RwLock` entirely.
static NON_DEFAULT: AtomicBool = AtomicBool::new(false);

/// Install `profile` as the process-wide active profile.  `source` is the
/// file it came from, if any (surfaced by `bdia info` / `/stats`).
pub fn set_active(profile: KernelProfile, source: Option<PathBuf>) {
    let non_default = !profile.is_default();
    *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) =
        Some(Active { profile: Arc::new(profile), source });
    NON_DEFAULT.store(non_default, Ordering::Release);
}

/// Drop back to the built-in default profile (today's constants).
pub fn reset_active() {
    NON_DEFAULT.store(false, Ordering::Release);
    *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) = None;
}

/// The active profile, if one was installed.
pub fn active() -> Option<Arc<KernelProfile>> {
    ACTIVE
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .map(|a| Arc::clone(&a.profile))
}

/// Identity of the active profile (`"default"` when none installed).
pub fn active_id() -> String {
    ACTIVE
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .map_or_else(|| "default".to_string(), |a| a.profile.id.clone())
}

/// File the active profile was loaded from, if any.
pub fn active_source() -> Option<PathBuf> {
    ACTIVE
        .read()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .and_then(|a| a.source.clone())
}

/// Parameters for one kernel invocation at the current pool width.  Also
/// notes the shape when recording is on (see [`record_shapes`]).
pub fn params_for(op: OpKind, m: usize, k: usize, n: usize) -> OpParams {
    let key = OpKey { op, m, k, n, threads: super::pool::threads() };
    if RECORD.load(Ordering::Relaxed) {
        RECORDED.lock().unwrap_or_else(|e| e.into_inner()).insert(key);
    }
    if !NON_DEFAULT.load(Ordering::Acquire) {
        return OpParams::DEFAULT;
    }
    match &*ACTIVE.read().unwrap_or_else(|e| e.into_inner()) {
        Some(a) => a.profile.params(&key),
        None => OpParams::DEFAULT,
    }
}

/// The active profile's fallback `grain_flop` — the single knob behind
/// `kernels::grain` that drives every row-parallel map (layernorm, GELU
/// maps, ...).
pub fn grain_flop() -> usize {
    if !NON_DEFAULT.load(Ordering::Acquire) {
        return OpParams::DEFAULT.grain_flop;
    }
    match &*ACTIVE.read().unwrap_or_else(|e| e.into_inner()) {
        Some(a) => a.profile.default_params.grain_flop,
        None => OpParams::DEFAULT.grain_flop,
    }
}

/// Rows per pool task for a given flop budget: tasks only get *larger*
/// or *smaller* — row partitioning itself never changes results.
pub fn grain_of(grain_flop: usize, work_per_row: usize) -> usize {
    (grain_flop / work_per_row.max(1)).max(1)
}

// ---------------------------------------------------------------------------
// Shape recording (used by `bdia tune` to learn what a model actually runs)
// ---------------------------------------------------------------------------

static RECORD: AtomicBool = AtomicBool::new(false);
static RECORDED: Mutex<BTreeSet<OpKey>> = Mutex::new(BTreeSet::new());

/// Start (clearing any previous set) or stop recording every
/// (op, dims, threads) key the kernels look up.
pub fn record_shapes(on: bool) {
    if on {
        RECORDED.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
    RECORD.store(on, Ordering::Relaxed);
}

/// Drain the recorded keys, sorted.
pub fn take_recorded() -> Vec<OpKey> {
    let mut g = RECORDED.lock().unwrap_or_else(|e| e.into_inner());
    std::mem::take(&mut *g).into_iter().collect()
}

/// Serializes unit tests that assert on the process-global active profile
/// or the keyed-cache counters (libtest runs tests concurrently).
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> KernelProfile {
        let mut p = KernelProfile {
            id: "vit_s10-t2".into(),
            ..KernelProfile::default()
        };
        p.entries.insert(
            OpKey { op: OpKind::Matmul, m: 128, k: 192, n: 192, threads: 2 },
            OpParams { kc: 128, grain_flop: 1 << 12, unroll: 8, nt_cache: false },
        );
        p.entries.insert(
            OpKey { op: OpKind::MatmulNt, m: 128, k: 192, n: 192, threads: 2 },
            OpParams { kc: 32, grain_flop: 1 << 16, unroll: 4, nt_cache: true },
        );
        p
    }

    #[test]
    fn default_profile_reproduces_todays_constants() {
        let d = KernelProfile::default();
        assert!(d.is_default());
        assert_eq!(d.version, PROFILE_VERSION);
        let p = d.params(&OpKey {
            op: OpKind::Matmul,
            m: 7,
            k: 9,
            n: 11,
            threads: 3,
        });
        assert_eq!(p, OpParams { kc: 64, grain_flop: 1 << 14, unroll: 1, nt_cache: false });
        // the one grain heuristic behind every row-parallel kernel
        assert_eq!(grain_of(OpParams::DEFAULT.grain_flop, 4), 1 << 12);
        assert_eq!(grain_of(OpParams::DEFAULT.grain_flop, 0), 1 << 14);
        assert_eq!(grain_of(OpParams::DEFAULT.grain_flop, usize::MAX), 1);
    }

    #[test]
    fn json_round_trip_is_byte_identical_and_lossless() {
        let p = sample();
        let s1 = p.to_json_string();
        let back = KernelProfile::from_json(&s1).expect("round trip");
        assert_eq!(back, p);
        assert_eq!(back.to_json_string(), s1);
        // entries shadow the fallback exactly where keyed
        let key = *p.entries.keys().next().unwrap();
        assert_eq!(back.params(&key), p.entries[&key]);
        let mut other = key;
        other.threads += 1;
        assert_eq!(back.params(&other), p.default_params);
    }

    #[test]
    fn corrupt_wrong_version_and_illegal_profiles_are_rejected() {
        assert!(KernelProfile::from_json("not json").is_err());
        assert!(KernelProfile::from_json("{\"id\": \"x\"}")
            .unwrap_err()
            .to_string()
            .contains("bdia_profile"));
        let wrong = sample().to_json_string().replacen(
            "\"bdia_profile\": 1",
            "\"bdia_profile\": 99",
            1,
        );
        let err = format!("{:#}", KernelProfile::from_json(&wrong).unwrap_err());
        assert!(err.contains("version 99"), "unhelpful error: {err}");
        // illegal unroll width
        let bad = sample().to_json_string().replacen(
            "\"unroll\": 1,",
            "\"unroll\": 3,",
            1,
        );
        let err = format!("{:#}", KernelProfile::from_json(&bad).unwrap_err());
        assert!(err.contains("unroll"), "unhelpful error: {err}");
        // kc = 0 is illegal
        assert!(OpParams { kc: 0, ..OpParams::DEFAULT }.validate().is_err());
    }

    #[test]
    fn save_is_atomic_and_loads_back_identically() {
        let dir = std::env::temp_dir()
            .join(format!("bdia_profile_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prof.json");
        let p = sample();
        p.save(&path).expect("save");
        // no tmp sibling left behind
        assert!(!dir.join("prof.json.tmp").exists());
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes, p.to_json_string().as_bytes());
        let back = KernelProfile::load(&path).expect("load");
        assert_eq!(back, p);
        std::fs::remove_dir_all(&dir).ok();
    }
}
