//! Workspace arena: per-thread recycling of f32 scratch buffers.
//!
//! Every kernel allocates its outputs and scratch through [`take`] and
//! hands short-lived buffers back with [`give`].  The free lists are
//! thread-local, so the trainer thread, each serving worker and each pool
//! worker reuse their own buffers call after call — in steady state the
//! hot path performs no fresh heap allocation for recurring shapes (the
//! buffers stay resident, avoiding both allocator traffic and first-touch
//! page faults).
//!
//! [`take`] zero-fills the returned buffer, so a recycled buffer is
//! indistinguishable from `vec![0.0; len]` — reuse can never change
//! results.  Buffers that escape into caches or tensors simply drop
//! normally; recycling is an optimization, never a requirement.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-thread free-list bound — beyond this, [`give`] lets buffers drop.
const MAX_CACHED: usize = 48;

thread_local! {
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// A zeroed `Vec<f32>` of length `len`, recycled when possible.
pub fn take(len: usize) -> Vec<f32> {
    let reused = FREE.with(|f| {
        let mut free = f.borrow_mut();
        // best fit: the smallest cached buffer that already has capacity
        let mut best: Option<usize> = None;
        for (i, v) in free.iter().enumerate() {
            if v.capacity() >= len
                && best.is_none_or(|b| v.capacity() < free[b].capacity())
            {
                best = Some(i);
            }
        }
        best.map(|i| free.swap_remove(i))
    });
    match reused {
        Some(mut v) => {
            HITS.fetch_add(1, Ordering::Relaxed);
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => {
            MISSES.fetch_add(1, Ordering::Relaxed);
            vec![0.0f32; len]
        }
    }
}

/// Return a buffer to this thread's free list for reuse.
pub fn give(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    FREE.with(|f| {
        let mut free = f.borrow_mut();
        if free.len() < MAX_CACHED {
            free.push(v);
        }
    });
}

/// Process-wide arena counters (surfaced by `bdia info` and `/stats`).
#[derive(Clone, Copy, Debug)]
pub struct WorkspaceStats {
    /// take() calls served from a recycled buffer
    pub hits: u64,
    /// take() calls that had to allocate
    pub misses: u64,
}

pub fn stats() -> WorkspaceStats {
    WorkspaceStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        // a size no other test uses, so best-fit must find exactly this
        // buffer again even if the thread's free list is shared
        let n = 123_457usize;
        let mut v = take(n);
        assert!(v.iter().all(|&x| x == 0.0));
        v.iter_mut().for_each(|x| *x = 1.5);
        let ptr = v.as_ptr();
        give(v);
        let v2 = take(n);
        assert_eq!(v2.len(), n);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer not zeroed");
        assert_eq!(v2.as_ptr(), ptr, "expected the recycled allocation");
        give(v2);
    }

    #[test]
    fn oversized_requests_fall_through_to_fresh_allocation() {
        give(take(4));
        let big = take(1 << 16);
        assert_eq!(big.len(), 1 << 16);
        assert!(big.iter().all(|&x| x == 0.0));
        let s = stats();
        assert!(s.hits + s.misses > 0);
    }
}
