//! Workspace arena: per-thread recycling of f32 scratch buffers.
//!
//! Every kernel allocates its outputs and scratch through [`take`] and
//! hands short-lived buffers back with [`give`].  The free lists are
//! thread-local, so the trainer thread, each serving worker and each pool
//! worker reuse their own buffers call after call — in steady state the
//! hot path performs no fresh heap allocation for recurring shapes (the
//! buffers stay resident, avoiding both allocator traffic and first-touch
//! page faults).
//!
//! [`take`] zero-fills the returned buffer, so a recycled buffer is
//! indistinguishable from `vec![0.0; len]` — reuse can never change
//! results.  Buffers that escape into caches or tensors simply drop
//! normally; recycling is an optimization, never a requirement.
//!
//! A second, *keyed* cache ([`take_keyed`]) memoizes derived buffers —
//! today the `matmul_nt_w` weight transpose — keyed by the source slice's
//! pointer + length + the process-wide **weight generation**.  Any code
//! path that mutates or replaces long-lived weight buffers bumps
//! [`bump_weight_generation`], which invalidates every memoized derivation
//! at once; the optimizer step, parameter (re)initialization and
//! checkpoint-restore paths in-tree all do.

use crate::obs::Counter;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Per-thread free-list bound — beyond this, [`give`] lets buffers drop.
const MAX_CACHED: usize = 48;

/// Per-thread keyed-cache bound.  Must comfortably exceed the number of
/// distinct weight matrices a model's backward pass touches per step
/// (K blocks × several weights each), or cyclic access would evict every
/// entry before its next use.
const MAX_KEYED: usize = 64;

thread_local! {
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static KEYED: RefCell<Vec<KeyedEntry>> = const { RefCell::new(Vec::new()) };
}

/// Arena counters, registered in the process-wide metric registry so the
/// same cells feed [`stats`], `/stats` and the `/metrics` exposition.
struct ArenaCounters {
    hits: Counter,
    misses: Counter,
    keyed_hits: Counter,
    keyed_builds: Counter,
}

fn counters() -> &'static ArenaCounters {
    static CELL: OnceLock<ArenaCounters> = OnceLock::new();
    CELL.get_or_init(|| {
        let reg = crate::obs::metrics::global();
        ArenaCounters {
            hits: reg.counter("bdia_workspace_hits_total", "arena take() recycles"),
            misses: reg.counter("bdia_workspace_misses_total", "arena take() allocations"),
            keyed_hits: reg.counter("bdia_workspace_keyed_hits_total", "keyed-cache hits"),
            keyed_builds: reg.counter("bdia_workspace_keyed_builds_total", "keyed-cache builds"),
        }
    })
}

/// Bumped whenever long-lived weight buffers may have been mutated,
/// dropped or replaced; stale keyed entries can then never match.
static WEIGHT_GEN: AtomicU64 = AtomicU64::new(0);

struct KeyedEntry {
    /// (source pointer, source length, weight generation at build time).
    key: (usize, usize, u64),
    buf: Rc<Vec<f32>>,
}

/// Invalidate every keyed (derived-from-weights) cache entry process-wide.
pub fn bump_weight_generation() {
    WEIGHT_GEN.fetch_add(1, Ordering::Relaxed);
}

/// Current weight generation (keyed-cache entries are pinned to one).
pub fn weight_generation() -> u64 {
    WEIGHT_GEN.load(Ordering::Relaxed)
}

/// A buffer derived from the long-lived slice `src`, memoized per thread.
///
/// On a hit the previously built buffer is returned as-is; on a miss a
/// zeroed buffer of `out_len` is passed to `build` and the result cached
/// under `(src.as_ptr(), src.len(), weight_generation())`.  Callers must
/// guarantee `src` is a long-lived buffer whose every mutation path bumps
/// [`bump_weight_generation`] — that is what makes pointer identity a
/// sound cache key (a freed-and-reallocated buffer can reuse an address,
/// but never within the same generation, because dropping a weight store
/// bumps the generation first).
pub fn take_keyed(
    src: &[f32],
    out_len: usize,
    build: impl FnOnce(&mut [f32]),
) -> Rc<Vec<f32>> {
    let key = (src.as_ptr() as usize, src.len(), weight_generation());
    KEYED.with(|cell| {
        let mut cache = cell.borrow_mut();
        if let Some(e) =
            cache.iter().find(|e| e.key == key && e.buf.len() == out_len)
        {
            counters().keyed_hits.inc();
            return Rc::clone(&e.buf);
        }
        let mut v = vec![0.0f32; out_len];
        build(&mut v);
        let buf = Rc::new(v);
        // drop entries from dead generations, then bound the cache FIFO
        cache.retain(|e| e.key.2 == key.2);
        if cache.len() >= MAX_KEYED {
            cache.remove(0);
        }
        cache.push(KeyedEntry { key, buf: Rc::clone(&buf) });
        counters().keyed_builds.inc();
        buf
    })
}

/// A zeroed `Vec<f32>` of length `len`, recycled when possible.
pub fn take(len: usize) -> Vec<f32> {
    let reused = FREE.with(|f| {
        let mut free = f.borrow_mut();
        // best fit: the smallest cached buffer that already has capacity
        let mut best: Option<usize> = None;
        for (i, v) in free.iter().enumerate() {
            if v.capacity() >= len
                && best.is_none_or(|b| v.capacity() < free[b].capacity())
            {
                best = Some(i);
            }
        }
        best.map(|i| free.swap_remove(i))
    });
    match reused {
        Some(mut v) => {
            counters().hits.inc();
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => {
            counters().misses.inc();
            vec![0.0f32; len]
        }
    }
}

/// Return a buffer to this thread's free list for reuse.
pub fn give(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    FREE.with(|f| {
        let mut free = f.borrow_mut();
        if free.len() < MAX_CACHED {
            free.push(v);
        }
    });
}

/// Process-wide arena counters (surfaced by `bdia info` and `/stats`).
#[derive(Clone, Copy, Debug)]
pub struct WorkspaceStats {
    /// take() calls served from a recycled buffer
    pub hits: u64,
    /// take() calls that had to allocate
    pub misses: u64,
    /// take_keyed() calls served from a memoized buffer (nt-cache hits)
    pub keyed_hits: u64,
    /// take_keyed() calls that had to build (nt-cache misses)
    pub keyed_builds: u64,
}

pub fn stats() -> WorkspaceStats {
    let c = counters();
    WorkspaceStats {
        hits: c.hits.get(),
        misses: c.misses.get(),
        keyed_hits: c.keyed_hits.get(),
        keyed_builds: c.keyed_builds.get(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses_capacity() {
        // a size no other test uses, so best-fit must find exactly this
        // buffer again even if the thread's free list is shared
        let n = 123_457usize;
        let mut v = take(n);
        assert!(v.iter().all(|&x| x == 0.0));
        v.iter_mut().for_each(|x| *x = 1.5);
        let ptr = v.as_ptr();
        give(v);
        let v2 = take(n);
        assert_eq!(v2.len(), n);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffer not zeroed");
        assert_eq!(v2.as_ptr(), ptr, "expected the recycled allocation");
        give(v2);
    }

    #[test]
    fn oversized_requests_fall_through_to_fresh_allocation() {
        give(take(4));
        let big = take(1 << 16);
        assert_eq!(big.len(), 1 << 16);
        assert!(big.iter().all(|&x| x == 0.0));
        let s = stats();
        assert!(s.hits + s.misses > 0);
    }

    #[test]
    fn keyed_cache_hits_on_same_source_and_invalidates_on_generation_bump() {
        let src: Vec<f32> = (0..64).map(|i| i as f32).collect();
        fn fill(out: &mut [f32]) {
            for (i, o) in out.iter_mut().enumerate() {
                *o = i as f32 * 2.0;
            }
        }
        // concurrent tests bump the weight generation (optimizer steps,
        // checkpoint decodes), which legitimately invalidates this cache;
        // retry until both calls land inside one generation
        let (first, second) = loop {
            let gen = weight_generation();
            let a = take_keyed(&src, 64, fill);
            let b = take_keyed(&src, 64, fill);
            if weight_generation() == gen {
                break (a, b);
            }
        };
        // the second call must have been a hit: same Rc allocation
        assert!(Rc::ptr_eq(&first, &second), "expected a keyed-cache hit");
        assert_eq!(first.as_slice(), second.as_slice());
        // a generation bump invalidates: a fresh buffer is built
        bump_weight_generation();
        let third = take_keyed(&src, 64, fill);
        assert!(!Rc::ptr_eq(&first, &third));
        assert_eq!(first.as_slice(), third.as_slice());
        let s = stats();
        assert!(s.keyed_hits >= 1 && s.keyed_builds >= 2);
    }
}
