//! Cache-blocked, row-parallel matmul family.
//!
//! Every variant partitions work across **output rows only** (the m-dim of
//! `c`): each output element is produced by exactly one task, and its
//! reduction runs in the same ascending index order as the scalar
//! reference loop, so results are bit-identical for any thread count and
//! any block size.
//!
//! IEEE faithfulness: the seed interpreter skipped `a == 0.0` terms, which
//! silently dropped `0.0 * inf = NaN` and signed-zero contributions.  The
//! kernels here have **no value-dependent control flow** — every term is
//! accumulated — so they are bit-faithful to the plain summation (and
//! branch-predictable, which is also what the auto-vectorizer wants).

use super::pool;
use super::workspace;

/// k-dimension panel height: one panel of `b` (`KC x n`) stays hot in L2
/// while it is swept over all rows of a task's chunk.  Tiling only groups
/// iterations — the per-element accumulation order stays `0..k` ascending.
const KC: usize = 64;

/// Target work (multiply-adds) per parallel task; below this, fan-out
/// overhead beats the win and the kernels run inline.
const GRAIN_FLOP: usize = 1 << 14;

/// Minimum rows per task so each task amortizes `GRAIN_FLOP`.
pub(crate) fn row_grain(work_per_row: usize) -> usize {
    (GRAIN_FLOP / work_per_row.max(1)).max(1)
}

/// Shared core: `c(m,n) = a(m,k) @ b(k,n) [+ bias]`, bias added per row
/// after the full k-reduction (same per-element order as matmul-then-add).
fn mm_bias(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = workspace::take(m * n);
    pool::for_rows(&mut c, n, row_grain(k * n), |i0, rows| {
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for (ri, crow) in rows.chunks_exact_mut(n).enumerate() {
                let arow = &a[(i0 + ri) * k..(i0 + ri) * k + k];
                for p in kb..kend {
                    let av = arow[p];
                    let brow = &b[p * n..(p + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * *bv;
                    }
                }
            }
        }
        if let Some(bs) = bias {
            for crow in rows.chunks_exact_mut(n) {
                for (cv, bv) in crow.iter_mut().zip(bs) {
                    *cv += *bv;
                }
            }
        }
    });
    c
}

/// c(m,n) = a(m,k) @ b(k,n)
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    mm_bias(a, b, None, m, k, n)
}

/// y(rows, d_out) = x(rows, d_in) @ w(d_in, d_out) + bias
pub fn linear(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
) -> Vec<f32> {
    debug_assert_eq!(bias.len(), d_out);
    mm_bias(x, w, Some(bias), rows, d_in, d_out)
}

/// c(k,n) = a(m,k)^T @ b(m,n)
///
/// The reduction runs over m; each task owns a contiguous band of output
/// rows and performs its own full `i = 0..m` sweep, so per-element order
/// is `i` ascending regardless of the thread count.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = workspace::take(k * n);
    pool::for_rows(&mut c, n, row_grain(m * n), |p0, rows| {
        debug_assert!(p0 + rows.len() / n <= k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (pr, crow) in rows.chunks_exact_mut(n).enumerate() {
                let av = arow[p0 + pr];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * *bv;
                }
            }
        }
    });
    c
}

/// c(m,k) = a(m,n) @ b(k,n)^T
///
/// `b` is transposed once into a workspace buffer (the "cached weight
/// transpose"), turning the inner loop into a vectorizable axpy while
/// keeping the per-element reduction order identical to the dot-product
/// form: `jj = 0..n` ascending.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut bt = workspace::take(n * k);
    for p in 0..k {
        let brow = &b[p * n..(p + 1) * n];
        for (jj, bv) in brow.iter().enumerate() {
            bt[jj * k + p] = *bv;
        }
    }
    let mut c = workspace::take(m * k);
    pool::for_rows(&mut c, k, row_grain(n * k), |i0, rows| {
        for (ri, crow) in rows.chunks_exact_mut(k).enumerate() {
            let arow = &a[(i0 + ri) * n..(i0 + ri) * n + n];
            for (jj, av) in arow.iter().enumerate() {
                let btrow = &bt[jj * k..(jj + 1) * k];
                for (cv, bv) in crow.iter_mut().zip(btrow) {
                    *cv += *av * *bv;
                }
            }
        }
    });
    workspace::give(bt);
    c
}

#[cfg(test)]
mod tests {
    use super::super::pool::set_threads;
    use super::*;
    use crate::tensor::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_identity_and_transpose_agree() {
        // a (2,3) @ b (3,2)
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
        // a^T @ a via matmul_tn equals explicit transpose product
        let ata = matmul_tn(&a, &a, 2, 3, 3);
        let at = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        let ata2 = matmul(&at, &a, 3, 2, 3);
        assert_eq!(ata, ata2);
        // a @ b^T with b (2,3)
        let abt = matmul_nt(&a, &a, 2, 3, 2);
        assert_eq!(abt, vec![14.0, 32.0, 32.0, 77.0]);
    }

    #[test]
    fn blocked_parallel_matmul_bit_matches_naive_across_thread_counts() {
        let mut rng = Rng::new(0);
        // sizes straddling the KC panel and the parallel grain
        for (m, k, n) in [(1usize, 3usize, 5usize), (17, 70, 9), (64, 130, 33)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let want = naive(&a, &b, m, k, n);
            for t in [1usize, 2, 4, 7] {
                set_threads(t);
                let got = matmul(&a, &b, m, k, n);
                assert_eq!(
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "matmul {m}x{k}x{n} at {t} threads"
                );
            }
        }
        set_threads(0);
    }

    #[test]
    fn matmul_is_ieee_faithful_to_plain_summation() {
        // the seed skipped a == 0.0 terms, silently turning 0 * inf into 0;
        // the blocked kernels must propagate the NaN like plain summation
        let a = vec![0.0f32, 1.0];
        let b = vec![f32::INFINITY, 2.0, 1.0, 3.0]; // (2,2)
        let c = matmul(&a, &b, 1, 2, 2);
        assert!(c[0].is_nan(), "0 * inf must produce NaN, got {}", c[0]);
        assert_eq!(c[1], 0.0 * 2.0 + 1.0 * 3.0);

        // a(1,2)^T @ [inf, 3]: c[0][*] = 0.0 * row -> NaN in column 0
        let b2 = vec![f32::INFINITY, 3.0];
        let ct = matmul_tn(&a, &b2, 1, 2, 2);
        assert!(ct[0].is_nan(), "matmul_tn dropped the 0 * inf term");
        assert_eq!(ct[2], f32::INFINITY);
        assert_eq!(ct[3], 3.0);
    }

    #[test]
    fn linear_adds_bias_after_full_reduction() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let w = vec![1.0f32, 0.0, 0.0, 1.0];
        let bias = vec![10.0f32, 20.0];
        let y = linear(&x, &w, &bias, 2, 2, 2);
        assert_eq!(y, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn matmul_nt_transpose_cache_matches_dot_form() {
        let mut rng = Rng::new(3);
        let (m, n, k) = (13usize, 41usize, 19usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        // dot-product reference: s += a[i][jj] * b[p][jj], jj ascending
        let mut want = vec![0.0f32; m * k];
        for i in 0..m {
            for p in 0..k {
                let mut s = 0.0f32;
                for jj in 0..n {
                    s += a[i * n + jj] * b[p * n + jj];
                }
                want[i * k + p] = s;
            }
        }
        for t in [1usize, 3] {
            set_threads(t);
            let got = matmul_nt(&a, &b, m, n, k);
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matmul_nt at {t} threads"
            );
        }
        set_threads(0);
    }
}
