//! Cache-blocked, row-parallel matmul family.
//!
//! Every variant partitions work across **output rows only** (the m-dim of
//! `c`): each output element is produced by exactly one task, and its
//! reduction runs in the same ascending index order as the scalar
//! reference loop, so results are bit-identical for any thread count and
//! any block size.
//!
//! The block size `kc`, the rows-per-task grain and the inner-loop chunk
//! width come from the active [`profile`](super::profile) (`bdia tune`);
//! the default profile reproduces the historical constants bit-for-bit,
//! and any legal profile yields identical bits by construction — the knobs
//! regroup loops and move task boundaries, never the per-element
//! reduction order.
//!
//! IEEE faithfulness: the seed interpreter skipped `a == 0.0` terms, which
//! silently dropped `0.0 * inf = NaN` and signed-zero contributions.  The
//! kernels here have **no value-dependent control flow** — every term is
//! accumulated — so they are bit-faithful to the plain summation (and
//! branch-predictable, which is also what the auto-vectorizer wants).

use super::elementwise::axpy;
use super::pool;
use super::profile::{self, OpKind, OpParams};
use super::workspace;

/// Shared core: `c(m,n) = a(m,k) @ b(k,n) [+ bias]`, bias added per row
/// after the full k-reduction (same per-element order as matmul-then-add).
///
/// One k-panel of `b` (`kc x n`) stays hot in L2 while it is swept over
/// all rows of a task's chunk.  Tiling only groups iterations — the
/// per-element accumulation order stays `0..k` ascending for any `kc`.
fn mm_bias(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let prm = profile::params_for(OpKind::Matmul, m, k, n);
    let kc = prm.kc.max(1);
    let mut c = workspace::take(m * n);
    pool::for_rows(&mut c, n, profile::grain_of(prm.grain_flop, k * n), |i0, rows| {
        for kb in (0..k).step_by(kc) {
            let kend = (kb + kc).min(k);
            for (ri, crow) in rows.chunks_exact_mut(n).enumerate() {
                let arow = &a[(i0 + ri) * k..(i0 + ri) * k + k];
                for p in kb..kend {
                    let brow = &b[p * n..(p + 1) * n];
                    axpy(crow, arow[p], brow, prm.unroll);
                }
            }
        }
        if let Some(bs) = bias {
            for crow in rows.chunks_exact_mut(n) {
                for (cv, bv) in crow.iter_mut().zip(bs) {
                    *cv += *bv;
                }
            }
        }
    });
    c
}

/// c(m,n) = a(m,k) @ b(k,n)
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    mm_bias(a, b, None, m, k, n)
}

/// y(rows, d_out) = x(rows, d_in) @ w(d_in, d_out) + bias
pub fn linear(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
) -> Vec<f32> {
    debug_assert_eq!(bias.len(), d_out);
    mm_bias(x, w, Some(bias), rows, d_in, d_out)
}

/// c(k,n) = a(m,k)^T @ b(m,n)
///
/// The reduction runs over m; each task owns a contiguous band of output
/// rows and performs its own full `i = 0..m` sweep (grouped into `kc`
/// panels that keep order `i` ascending), so per-element order never
/// depends on the thread count or the profile.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let prm = profile::params_for(OpKind::MatmulTn, m, k, n);
    let kc = prm.kc.max(1);
    let mut c = workspace::take(k * n);
    pool::for_rows(&mut c, n, profile::grain_of(prm.grain_flop, m * n), |p0, rows| {
        debug_assert!(p0 + rows.len() / n <= k);
        for ib in (0..m).step_by(kc) {
            let iend = (ib + kc).min(m);
            for i in ib..iend {
                let arow = &a[i * k..(i + 1) * k];
                let brow = &b[i * n..(i + 1) * n];
                for (pr, crow) in rows.chunks_exact_mut(n).enumerate() {
                    axpy(crow, arow[p0 + pr], brow, prm.unroll);
                }
            }
        }
    });
    c
}

/// Scatter `b(k,n)` into `bt(n,k)` so the nt inner loop reads rows.
fn transpose_into(bt: &mut [f32], b: &[f32], k: usize, n: usize) {
    for p in 0..k {
        let brow = &b[p * n..(p + 1) * n];
        for (jj, bv) in brow.iter().enumerate() {
            bt[jj * k + p] = *bv;
        }
    }
}

/// The nt compute core over an already-transposed `bt(n,k)`: per-element
/// reduction order is `jj = 0..n` ascending (panels regroup, never
/// reorder), identical to the dot-product form.
fn nt_core(
    a: &[f32],
    bt: &[f32],
    m: usize,
    n: usize,
    k: usize,
    prm: OpParams,
) -> Vec<f32> {
    let kc = prm.kc.max(1);
    let mut c = workspace::take(m * k);
    pool::for_rows(&mut c, k, profile::grain_of(prm.grain_flop, n * k), |i0, rows| {
        for jb in (0..n).step_by(kc) {
            let jend = (jb + kc).min(n);
            for (ri, crow) in rows.chunks_exact_mut(k).enumerate() {
                let arow = &a[(i0 + ri) * n..(i0 + ri) * n + n];
                for jj in jb..jend {
                    let btrow = &bt[jj * k..(jj + 1) * k];
                    axpy(crow, arow[jj], btrow, prm.unroll);
                }
            }
        }
    });
    c
}

/// c(m,k) = a(m,n) @ b(k,n)^T
///
/// `b` is transposed once into a workspace buffer, turning the inner loop
/// into a vectorizable axpy while keeping the per-element reduction order
/// identical to the dot-product form: `jj = 0..n` ascending.  The
/// transpose is rebuilt every call — `b` may be any caller buffer.  For
/// long-lived weight matrices use [`matmul_nt_w`], which can reuse a
/// cached transpose under a tuned profile.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let prm = profile::params_for(OpKind::MatmulNt, m, n, k);
    let mut bt = workspace::take(n * k);
    transpose_into(&mut bt, b, k, n);
    let c = nt_core(a, &bt, m, n, k, prm);
    workspace::give(bt);
    c
}

/// c(m,k) = a(m,n) @ b(k,n)^T where `b` is a **long-lived weight matrix**.
///
/// Bit-identical to [`matmul_nt`] always.  When the active profile enables
/// `nt_cache`, the transpose of `b` is served from the thread-local keyed
/// workspace cache instead of being rebuilt per call — a pure re-read of
/// previously computed bits, so results cannot change.
///
/// Contract: `b` must be a buffer that outlives the cache entry and whose
/// every mutation/replacement path bumps
/// [`workspace::bump_weight_generation`] (the optimizer step, parameter
/// (re)initialization and checkpoint-restore paths in-tree all do).  Do
/// NOT call this with transient buffers — use [`matmul_nt`] for those.
pub fn matmul_nt_w(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let prm = profile::params_for(OpKind::MatmulNt, m, n, k);
    if !prm.nt_cache {
        let mut bt = workspace::take(n * k);
        transpose_into(&mut bt, b, k, n);
        let c = nt_core(a, &bt, m, n, k, prm);
        workspace::give(bt);
        return c;
    }
    let bt = workspace::take_keyed(b, n * k, |bt| transpose_into(bt, b, k, n));
    nt_core(a, &bt, m, n, k, prm)
}

#[cfg(test)]
mod tests {
    use super::super::pool::set_threads;
    use super::super::profile::{
        reset_active, set_active, KernelProfile, OpParams,
    };
    use super::*;
    use crate::tensor::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_identity_and_transpose_agree() {
        // a (2,3) @ b (3,2)
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![4.0, 5.0, 10.0, 11.0]);
        // a^T @ a via matmul_tn equals explicit transpose product
        let ata = matmul_tn(&a, &a, 2, 3, 3);
        let at = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        let ata2 = matmul(&at, &a, 3, 2, 3);
        assert_eq!(ata, ata2);
        // a @ b^T with b (2,3)
        let abt = matmul_nt(&a, &a, 2, 3, 2);
        assert_eq!(abt, vec![14.0, 32.0, 32.0, 77.0]);
    }

    #[test]
    fn blocked_parallel_matmul_bit_matches_naive_across_thread_counts() {
        let mut rng = Rng::new(0);
        // sizes straddling the KC panel and the parallel grain
        for (m, k, n) in [(1usize, 3usize, 5usize), (17, 70, 9), (64, 130, 33)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let want = naive(&a, &b, m, k, n);
            for t in [1usize, 2, 4, 7] {
                set_threads(t);
                let got = matmul(&a, &b, m, k, n);
                assert_eq!(
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "matmul {m}x{k}x{n} at {t} threads"
                );
            }
        }
        set_threads(0);
    }

    #[test]
    fn matmul_is_ieee_faithful_to_plain_summation() {
        // the seed skipped a == 0.0 terms, silently turning 0 * inf into 0;
        // the blocked kernels must propagate the NaN like plain summation
        let a = vec![0.0f32, 1.0];
        let b = vec![f32::INFINITY, 2.0, 1.0, 3.0]; // (2,2)
        let c = matmul(&a, &b, 1, 2, 2);
        assert!(c[0].is_nan(), "0 * inf must produce NaN, got {}", c[0]);
        assert_eq!(c[1], 0.0 * 2.0 + 1.0 * 3.0);

        // a(1,2)^T @ [inf, 3]: c[0][*] = 0.0 * row -> NaN in column 0
        let b2 = vec![f32::INFINITY, 3.0];
        let ct = matmul_tn(&a, &b2, 1, 2, 2);
        assert!(ct[0].is_nan(), "matmul_tn dropped the 0 * inf term");
        assert_eq!(ct[2], f32::INFINITY);
        assert_eq!(ct[3], 3.0);
    }

    #[test]
    fn linear_adds_bias_after_full_reduction() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let w = vec![1.0f32, 0.0, 0.0, 1.0];
        let bias = vec![10.0f32, 20.0];
        let y = linear(&x, &w, &bias, 2, 2, 2);
        assert_eq!(y, vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn matmul_nt_transpose_cache_matches_dot_form() {
        let mut rng = Rng::new(3);
        let (m, n, k) = (13usize, 41usize, 19usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        // dot-product reference: s += a[i][jj] * b[p][jj], jj ascending
        let mut want = vec![0.0f32; m * k];
        for i in 0..m {
            for p in 0..k {
                let mut s = 0.0f32;
                for jj in 0..n {
                    s += a[i * n + jj] * b[p * n + jj];
                }
                want[i * k + p] = s;
            }
        }
        for t in [1usize, 3] {
            set_threads(t);
            let got = matmul_nt(&a, &b, m, n, k);
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matmul_nt at {t} threads"
            );
        }
        set_threads(0);
    }

    #[test]
    fn matmul_nt_w_cached_transpose_is_bit_identical_and_hits() {
        let _guard = super::super::profile::test_lock();
        let mut rng = Rng::new(7);
        let (m, n, k) = (9usize, 37usize, 21usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        // the "weight": long-lived for the whole test, as the contract asks
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        reset_active();
        let want = matmul_nt(&a, &w, m, n, k);

        // uncached under the default profile (nt_cache = false)
        let got = matmul_nt_w(&a, &w, m, n, k);
        assert_eq!(want, got, "nt_w (uncached) differs from nt");

        // enable the cache and prove bit-identity plus an actual hit
        let profile = KernelProfile {
            default_params: OpParams { nt_cache: true, ..OpParams::DEFAULT },
            id: "nt-cache-test".into(),
            ..KernelProfile::default()
        };
        set_active(profile, None);
        crate::kernels::workspace::bump_weight_generation();
        let before = crate::kernels::workspace::stats();
        // concurrent tests bump the weight generation (optimizer steps,
        // checkpoint decodes), which legitimately invalidates the cache;
        // retry until both calls land inside one generation
        let (first, second) = loop {
            let gen = crate::kernels::workspace::weight_generation();
            let f = matmul_nt_w(&a, &w, m, n, k); // builds the transpose
            let s = matmul_nt_w(&a, &w, m, n, k); // must hit the cache
            if crate::kernels::workspace::weight_generation() == gen {
                break (f, s);
            }
        };
        reset_active();
        let after = crate::kernels::workspace::stats();
        assert_eq!(want, first, "nt_w (cache build) differs from nt");
        assert_eq!(want, second, "nt_w (cache hit) differs from nt");
        assert!(
            after.keyed_builds >= before.keyed_builds + 1,
            "expected a transpose build"
        );
        assert!(
            after.keyed_hits >= before.keyed_hits + 1,
            "expected a transpose cache hit"
        );
    }
}
