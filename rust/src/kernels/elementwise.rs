//! Elementwise helpers and column reductions.
//!
//! Elementwise maps parallelize freely (each output element depends on one
//! input element).  Column reductions (`col_sum`) sum over rows in
//! ascending order, which is order-sensitive in f32 — they stay serial so
//! the grouping never depends on the thread count.

use super::pool;
use super::workspace;

/// Elements per task for cheap memory-bound maps: the unified grain
/// heuristic at a per-element cost weight of 4 flops (reproduces the old
/// `MAP_GRAIN = 1 << 12` under the default profile).
fn map_grain() -> usize {
    super::grain(4)
}

/// a += b
pub fn add_into(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += *y;
    }
}

/// `c[j] += a * b[j]` chunked at compile-time width `W` with a scalar
/// tail.  Every `c[j]` is an independent output element receiving exactly
/// one fused update, so the result is bit-identical to the scalar loop at
/// any width — the fixed-width chunks exist purely to hand the compiler
/// bounds-check-free, vectorizable bodies.
fn axpy_w<const W: usize>(c: &mut [f32], a: f32, b: &[f32]) {
    let n = c.len();
    debug_assert_eq!(n, b.len());
    let split = n - n % W;
    for (cc, bc) in
        c[..split].chunks_exact_mut(W).zip(b[..split].chunks_exact(W))
    {
        for (cv, bv) in cc.iter_mut().zip(bc) {
            *cv += a * *bv;
        }
    }
    for (cv, bv) in c[split..].iter_mut().zip(&b[split..]) {
        *cv += a * *bv;
    }
}

/// `c += a * b`, the profile-driven microkernel behind every matmul and
/// attention inner loop.  `unroll` selects the chunk width (1 = plain
/// scalar loop); all widths produce identical bits.
pub fn axpy(c: &mut [f32], a: f32, b: &[f32], unroll: usize) {
    match unroll {
        2 => axpy_w::<2>(c, a, b),
        4 => axpy_w::<4>(c, a, b),
        8 => axpy_w::<8>(c, a, b),
        16 => axpy_w::<16>(c, a, b),
        _ => {
            for (cv, bv) in c.iter_mut().zip(b) {
                *cv += a * *bv;
            }
        }
    }
}

/// out = a + b
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    let mut out = workspace::take(a.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = *x + *y;
    }
    out
}

/// Column sums of a (rows, cols) matrix — bias gradients.  Serial on
/// purpose: the row-sum order (`r` ascending) is part of the bit contract.
pub fn col_sum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = workspace::take(cols);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu default)
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

#[inline]
pub fn gelu(u: f32) -> f32 {
    let t = (GELU_C * (u + GELU_A * u * u * u)).tanh();
    0.5 * u * (1.0 + t)
}

#[inline]
pub fn gelu_grad(u: f32) -> f32 {
    let w = GELU_C * (u + GELU_A * u * u * u);
    let t = w.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * u * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * u * u)
}

/// out\[i\] = gelu(u\[i\]), row-parallel.
pub fn map_gelu(u: &[f32]) -> Vec<f32> {
    let mut out = workspace::take(u.len());
    pool::for_rows(&mut out, 1, map_grain(), |i0, chunk| {
        for (o, v) in chunk.iter_mut().zip(&u[i0..i0 + chunk.len()]) {
            *o = gelu(*v);
        }
    });
    out
}

/// du\[i\] *= gelu'(u\[i\]), row-parallel (the FFN backward chain).
pub fn scale_by_gelu_grad(du: &mut [f32], u: &[f32]) {
    debug_assert_eq!(du.len(), u.len());
    pool::for_rows(du, 1, map_grain(), |i0, chunk| {
        for (d, v) in chunk.iter_mut().zip(&u[i0..i0 + chunk.len()]) {
            *d *= gelu_grad(*v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for u in [-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let eps = 1e-3f32;
            let fd = (gelu(u + eps) - gelu(u - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_grad(u)).abs() < 1e-3,
                "u={u}: fd {fd} vs {}",
                gelu_grad(u)
            );
        }
        assert!((gelu(0.0)).abs() < 1e-7);
        // large positive ~ identity, large negative ~ 0
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn map_gelu_matches_scalar_gelu() {
        let u: Vec<f32> = (0..10_000).map(|i| (i as f32 - 5000.0) / 997.0).collect();
        let out = map_gelu(&u);
        for (o, v) in out.iter().zip(&u) {
            assert_eq!(o.to_bits(), gelu(*v).to_bits());
        }
        let mut du = vec![1.0f32; u.len()];
        scale_by_gelu_grad(&mut du, &u);
        for (d, v) in du.iter().zip(&u) {
            assert_eq!(d.to_bits(), gelu_grad(*v).to_bits());
        }
    }

    #[test]
    fn col_sum_sums_rows_in_order() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(col_sum(&a, 3, 2), vec![9.0, 12.0]);
        assert_eq!(add(&[1.0, 2.0], &[3.0, 4.0]), vec![4.0, 6.0]);
    }

    #[test]
    fn axpy_is_bit_identical_at_every_unroll_width() {
        // lengths straddle every chunk boundary; values include the IEEE
        // specials the scalar loop would produce (NaN, inf, -0.0)
        for len in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 31, 33, 100] {
            let b: Vec<f32> = (0..len)
                .map(|i| match i % 7 {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    2 => -0.0,
                    _ => (i as f32 - 3.5) * 0.37,
                })
                .collect();
            let base: Vec<f32> =
                (0..len).map(|i| (i as f32) * 0.11 - 1.0).collect();
            let mut want = base.clone();
            for (cv, bv) in want.iter_mut().zip(&b) {
                *cv += 1.7 * *bv;
            }
            for unroll in [1usize, 2, 4, 8, 16] {
                let mut c = base.clone();
                axpy(&mut c, 1.7, &b, unroll);
                for (got, exp) in c.iter().zip(&want) {
                    assert_eq!(
                        got.to_bits(),
                        exp.to_bits(),
                        "unroll={unroll} len={len}"
                    );
                }
            }
        }
    }
}
