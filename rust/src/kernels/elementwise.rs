//! Elementwise helpers and column reductions.
//!
//! Elementwise maps parallelize freely (each output element depends on one
//! input element).  Column reductions (`col_sum`) sum over rows in
//! ascending order, which is order-sensitive in f32 — they stay serial so
//! the grouping never depends on the thread count.

use super::pool;
use super::workspace;

/// Elements per task for cheap memory-bound maps.
const MAP_GRAIN: usize = 1 << 12;

/// a += b
pub fn add_into(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += *y;
    }
}

/// out = a + b
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    let mut out = workspace::take(a.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = *x + *y;
    }
    out
}

/// Column sums of a (rows, cols) matrix — bias gradients.  Serial on
/// purpose: the row-sum order (`r` ascending) is part of the bit contract.
pub fn col_sum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = workspace::take(cols);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu default)
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

#[inline]
pub fn gelu(u: f32) -> f32 {
    let t = (GELU_C * (u + GELU_A * u * u * u)).tanh();
    0.5 * u * (1.0 + t)
}

#[inline]
pub fn gelu_grad(u: f32) -> f32 {
    let w = GELU_C * (u + GELU_A * u * u * u);
    let t = w.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * u * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * u * u)
}

/// out\[i\] = gelu(u\[i\]), row-parallel.
pub fn map_gelu(u: &[f32]) -> Vec<f32> {
    let mut out = workspace::take(u.len());
    pool::for_rows(&mut out, 1, MAP_GRAIN, |i0, chunk| {
        for (o, v) in chunk.iter_mut().zip(&u[i0..i0 + chunk.len()]) {
            *o = gelu(*v);
        }
    });
    out
}

/// du\[i\] *= gelu'(u\[i\]), row-parallel (the FFN backward chain).
pub fn scale_by_gelu_grad(du: &mut [f32], u: &[f32]) {
    debug_assert_eq!(du.len(), u.len());
    pool::for_rows(du, 1, MAP_GRAIN, |i0, chunk| {
        for (d, v) in chunk.iter_mut().zip(&u[i0..i0 + chunk.len()]) {
            *d *= gelu_grad(*v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for u in [-3.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 3.0] {
            let eps = 1e-3f32;
            let fd = (gelu(u + eps) - gelu(u - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_grad(u)).abs() < 1e-3,
                "u={u}: fd {fd} vs {}",
                gelu_grad(u)
            );
        }
        assert!((gelu(0.0)).abs() < 1e-7);
        // large positive ~ identity, large negative ~ 0
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn map_gelu_matches_scalar_gelu() {
        let u: Vec<f32> = (0..10_000).map(|i| (i as f32 - 5000.0) / 997.0).collect();
        let out = map_gelu(&u);
        for (o, v) in out.iter().zip(&u) {
            assert_eq!(o.to_bits(), gelu(*v).to_bits());
        }
        let mut du = vec![1.0f32; u.len()];
        scale_by_gelu_grad(&mut du, &u);
        for (d, v) in du.iter().zip(&u) {
            assert_eq!(d.to_bits(), gelu_grad(*v).to_bits());
        }
    }

    #[test]
    fn col_sum_sums_rows_in_order() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(col_sum(&a, 3, 2), vec![9.0, 12.0]);
        assert_eq!(add(&[1.0, 2.0], &[3.0, 4.0]), vec![4.0, 6.0]);
    }
}
