//! Row-parallel layer norm, forward and backward.
//!
//! Semantics mirror the JAX model exactly (`python/compile/model.py`):
//! population variance, eps 1e-5.  Each row is normalised independently,
//! so the forward and the `dx` backward partition cleanly across rows; the
//! `dscale` / `dbias` column reductions stay serial because their row-sum
//! order is part of the bit contract.

use super::pool;
use super::workspace;

const LN_EPS: f32 = 1e-5;

/// Approximate flops per row for the grain calculation (several sweeps),
/// fed to the unified profile-driven grain heuristic.
fn ln_grain(d: usize) -> usize {
    super::grain(6 * d)
}

pub struct LnCache {
    /// normalised activations (rows, d)
    pub xhat: Vec<f32>,
    /// per-row 1/sqrt(var + eps)
    pub inv: Vec<f32>,
}

/// One contiguous band of rows of the LN forward.
fn ln_fwd_rows(
    scale: &[f32],
    bias: &[f32],
    x: &[f32],
    y: &mut [f32],
    xhat: &mut [f32],
    inv: &mut [f32],
    d: usize,
) {
    for (r, iv_out) in inv.iter_mut().enumerate() {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        *iv_out = iv;
        let xh = &mut xhat[r * d..(r + 1) * d];
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            let h = (xr[j] - mu) * iv;
            xh[j] = h;
            yr[j] = h * scale[j] + bias[j];
        }
    }
}

/// y = (x - mean) / sqrt(var + eps) * scale + bias, per row of length d.
pub fn ln_fwd(
    scale: &[f32],
    bias: &[f32],
    x: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, LnCache) {
    debug_assert_eq!(x.len(), rows * d);
    let mut y = workspace::take(rows * d);
    let mut xhat = workspace::take(rows * d);
    let mut inv = workspace::take(rows);
    let parts = pool::n_tasks(rows, ln_grain(d));
    if parts <= 1 {
        ln_fwd_rows(scale, bias, x, &mut y, &mut xhat, &mut inv, d);
    } else {
        let ys = pool::split_rows_mut(&mut y, d, parts);
        let xhs = pool::split_rows_mut(&mut xhat, d, parts);
        let invs = pool::split_rows_mut(&mut inv, 1, parts);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ys
            .into_iter()
            .zip(xhs)
            .zip(invs)
            .map(|((cy, cxh), cinv)| {
                let r0 = cy.row0;
                let nrows = cinv.rows.len();
                let xs = &x[r0 * d..(r0 + nrows) * d];
                Box::new(move || {
                    ln_fwd_rows(scale, bias, xs, cy.rows, cxh.rows, cinv.rows, d)
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::run_tasks(tasks);
    }
    (y, LnCache { xhat, inv })
}

/// Backward of [`ln_fwd`]: returns (dx, dscale, dbias).
pub fn ln_bwd(
    scale: &[f32],
    cache: &LnCache,
    dy: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), rows * d);
    let mut dx = workspace::take(rows * d);
    pool::for_rows(&mut dx, d, ln_grain(d), |r0, chunk| {
        for (ri, dxr) in chunk.chunks_exact_mut(d).enumerate() {
            let r = r0 + ri;
            let dyr = &dy[r * d..(r + 1) * d];
            let xh = &cache.xhat[r * d..(r + 1) * d];
            let iv = cache.inv[r];
            // dxhat = dy * scale; two row means close the LN jacobian
            let mut mean_dxh = 0.0f32;
            let mut mean_dxh_xh = 0.0f32;
            for j in 0..d {
                let dxh = dyr[j] * scale[j];
                mean_dxh += dxh;
                mean_dxh_xh += dxh * xh[j];
            }
            mean_dxh /= d as f32;
            mean_dxh_xh /= d as f32;
            for j in 0..d {
                let dxh = dyr[j] * scale[j];
                dxr[j] = iv * (dxh - mean_dxh - xh[j] * mean_dxh_xh);
            }
        }
    });
    // parameter grads: serial row sweep, r ascending (bit contract)
    let mut dscale = workspace::take(d);
    let mut dbias = workspace::take(d);
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &cache.xhat[r * d..(r + 1) * d];
        for j in 0..d {
            dscale[j] += dyr[j] * xh[j];
            dbias[j] += dyr[j];
        }
    }
    (dx, dscale, dbias)
}

impl LnCache {
    /// Hand the cache buffers back to the workspace arena.
    pub fn recycle(self) {
        workspace::give(self.xhat);
        workspace::give(self.inv);
    }
}

#[cfg(test)]
mod tests {
    use super::super::elementwise::col_sum;
    use super::*;
    use crate::tensor::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * s).collect()
    }

    #[test]
    fn ln_normalises_rows() {
        let mut rng = Rng::new(0);
        let d = 8;
        let x = randv(&mut rng, 2 * d, 3.0);
        let scale = vec![1.0; d];
        let bias = vec![0.0; d];
        let (y, _) = ln_fwd(&scale, &bias, &x, 2, d);
        for r in 0..2 {
            let row = &y[r * d..(r + 1) * d];
            let mu: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 =
                row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5, "mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn ln_bwd_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let d = 6;
        let rows = 2;
        let x = randv(&mut rng, rows * d, 1.0);
        let scale = randv(&mut rng, d, 0.5);
        let bias = randv(&mut rng, d, 0.5);
        let dy = randv(&mut rng, rows * d, 1.0);
        let (_, cache) = ln_fwd(&scale, &bias, &x, rows, d);
        let (dx, dscale, dbias) = ln_bwd(&scale, &cache, &dy, rows, d);

        // probe L = sum(dy * y): dL/dx == dx
        let eps = 1e-2f32;
        let probe = |xs: &[f32]| -> f64 {
            let (y, _) = ln_fwd(&scale, &bias, xs, rows, d);
            y.iter().zip(&dy).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        for idx in [0usize, 3, 7, rows * d - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
            let an = dx[idx];
            assert!(
                (fd - an).abs() < 2e-2 * an.abs().max(1.0),
                "dx[{idx}]: fd {fd} vs {an}"
            );
        }
        // dbias is just col-sum of dy
        let cs = col_sum(&dy, rows, d);
        for j in 0..d {
            assert!((dbias[j] - cs[j]).abs() < 1e-6);
        }
        assert_eq!(dscale.len(), d);
    }

    #[test]
    fn ln_fwd_bit_identical_across_thread_counts() {
        use super::super::pool::set_threads;
        let mut rng = Rng::new(5);
        // rows large enough that the parallel path actually engages
        let (rows, d) = (2048usize, 33usize);
        let x = randv(&mut rng, rows * d, 2.0);
        let scale = randv(&mut rng, d, 0.5);
        let bias = randv(&mut rng, d, 0.5);
        set_threads(1);
        let (y1, c1) = ln_fwd(&scale, &bias, &x, rows, d);
        for t in [2usize, 4, 7] {
            set_threads(t);
            let (y, c) = ln_fwd(&scale, &bias, &x, rows, d);
            assert_eq!(
                y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                c1.inv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c.inv.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            c.recycle();
        }
        c1.recycle();
        set_threads(0);
    }
}
