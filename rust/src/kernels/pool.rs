//! Deterministic persistent thread pool for the compute kernels.
//!
//! The pool parallelizes **only across output rows / examples**: work is
//! split into contiguous row ranges, each range is produced by exactly one
//! task, and every output element is computed with the identical sequential
//! instruction stream (same reduction order) as the single-threaded code.
//! Results are therefore bit-identical for *any* configured thread count —
//! the property the BDIA reversibility contract (eq. 24 reconstruction)
//! and the checkpoint/serving bit-exactness guarantees depend on.
//!
//! Design:
//!
//! * one process-wide pool (`set_threads` / `threads`), shared by the
//!   training loop and the serving worker path — workers are spawned
//!   lazily up to `threads() - 1` and persist for the process lifetime;
//! * [`run_tasks`] dispatches boxed closures to the workers, runs the
//!   first one on the calling thread, and blocks until every task has
//!   finished — which is what makes handing non-`'static` borrows to the
//!   persistent workers sound (see the SAFETY note);
//! * [`for_rows`] / [`split_rows_mut`] are the partitioning helpers: the
//!   split depends only on the row count and the configured thread count,
//!   never on data values.
//!
//! Rule: tasks must not call [`run_tasks`] themselves (no nested
//! parallel sections).  Kernels compose sequentially at the model layer
//! and parallelize only at the leaves, so this never happens in-tree; a
//! nested call could deadlock the fixed-size worker set.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool parallelism (a safety rail, not a tuning knob).
pub const MAX_THREADS: usize = 64;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    tx: Mutex<mpsc::Sender<Task>>,
    rx: Mutex<mpsc::Receiver<Task>>,
    /// Configured parallelism (>= 1).  Work is split into at most this
    /// many ranges; the calling thread always processes the first range.
    threads: AtomicUsize,
    /// Workers spawned so far (grown on demand, never shrunk).
    spawned: Mutex<usize>,
}

fn state() -> &'static PoolState {
    static S: OnceLock<PoolState> = OnceLock::new();
    S.get_or_init(|| {
        let (tx, rx) = mpsc::channel();
        PoolState {
            tx: Mutex::new(tx),
            rx: Mutex::new(rx),
            threads: AtomicUsize::new(auto_threads()),
            spawned: Mutex::new(0),
        }
    })
}

/// Default parallelism: every hardware thread the host offers.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// Configure pool parallelism (the `threads` config/CLI knob).
/// `0` selects [`auto_threads`].  Safe to call at any time: kernels read
/// the count per call, and results do not depend on it.
pub fn set_threads(n: usize) {
    let n = if n == 0 { auto_threads() } else { n.min(MAX_THREADS) };
    state().threads.store(n.max(1), Ordering::SeqCst);
}

/// Currently configured parallelism.
pub fn threads() -> usize {
    state().threads.load(Ordering::SeqCst)
}

/// Workers actually spawned so far (surfaced by `bdia info`).
pub fn spawned_workers() -> usize {
    *state().spawned.lock().unwrap()
}

fn ensure_workers(need: usize) {
    let s = state();
    let mut spawned = s.spawned.lock().unwrap();
    while *spawned < need {
        std::thread::Builder::new()
            .name(format!("bdia-kernel-{}", *spawned))
            .spawn(worker_loop)
            .expect("spawning kernel pool worker");
        *spawned += 1;
    }
}

fn worker_loop() {
    loop {
        // hold the receiver lock only while dequeuing, not while running
        let task = {
            let rx = state().rx.lock().unwrap();
            rx.recv()
        };
        match task {
            Ok(t) => t(), // wrapped: catches panics, always signals done
            Err(_) => break,
        }
    }
}

struct TaskSync {
    left: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

/// Decrements the remaining-task counter on drop, so a panicking task
/// still signals completion and `run_tasks` cannot hang.
struct DoneGuard(Arc<TaskSync>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let mut left = self.0.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// Blocks until all remote tasks finished — runs on unwind too, which is
/// what keeps `run_tasks`' borrow-lifetime argument airtight even if the
/// inline task panics.
struct WaitGuard<'a>(&'a TaskSync);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut left = self.0.left.lock().unwrap();
        while *left > 0 {
            left = self.0.cv.wait(left).unwrap();
        }
    }
}

/// Run a batch of independent tasks: task 0 on the calling thread, the
/// rest on the persistent workers.  Returns (or unwinds) only after every
/// task has completed, so tasks may borrow from the caller's stack.
pub fn run_tasks<'scope>(mut tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    match tasks.len() {
        0 => return,
        1 => {
            (tasks.pop().unwrap())();
            return;
        }
        _ => {}
    }
    let n_remote = tasks.len() - 1;
    ensure_workers(n_remote.min(MAX_THREADS - 1));
    let sync = Arc::new(TaskSync {
        left: Mutex::new(n_remote),
        cv: Condvar::new(),
        panicked: AtomicBool::new(false),
    });
    let inline = tasks.remove(0);
    // Wrap every remote task up front.  Each wrapper OWNS its DoneGuard
    // (captured by value), so the counter is decremented exactly once per
    // wrapper — when the task finishes running, when it unwinds, or when
    // the wrapper is dropped unexecuted (e.g. a panic mid-dispatch drops
    // the rest of this Vec).  That makes WaitGuard's wait terminate on
    // every path.
    let wrapped_tasks: Vec<Task> = tasks
        .into_iter()
        .map(|t| {
            let s = Arc::clone(&sync);
            let done = DoneGuard(Arc::clone(&sync));
            let wrapped: Box<dyn FnOnce() + Send + 'scope> =
                Box::new(move || {
                    let _done = done;
                    if catch_unwind(AssertUnwindSafe(t)).is_err() {
                        s.panicked.store(true, Ordering::SeqCst);
                    }
                });
            // SAFETY: the closure borrows data living at least for
            // 'scope.  `run_tasks` does not return — not even by
            // unwinding, thanks to the WaitGuard armed before any task
            // is sent — until every wrapper's DoneGuard has signalled,
            // so the erased lifetime can never be observed dangling.
            unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(wrapped)
            }
        })
        .collect();
    {
        // armed BEFORE the first send: an unwind out of this block waits
        // for everything already queued (the unsent remainder drops and
        // self-signals via its owned guards)
        let _wait = WaitGuard(&sync);
        {
            let tx = state().tx.lock().unwrap();
            for w in wrapped_tasks {
                tx.send(w).expect("kernel pool queue closed");
            }
        }
        inline();
        // _wait drops here: blocks until all remote tasks are done
    }
    debug_assert_eq!(*sync.left.lock().unwrap(), 0);
    if sync.panicked.load(Ordering::SeqCst) {
        panic!("kernel pool task panicked");
    }
}

/// How many parallel tasks to use for `items` work items when each task
/// should own at least `grain` of them.  Depends only on the configured
/// thread count and the item count — never on data values — and the
/// per-item arithmetic is identical either way, so any return value
/// yields bit-identical results.
pub fn n_tasks(items: usize, grain: usize) -> usize {
    if items == 0 {
        return 1;
    }
    (items / grain.max(1)).clamp(1, threads())
}

/// A contiguous range of rows handed to one task.
pub struct RowChunk<'a, T> {
    /// Global index of the first row in `rows`.
    pub row0: usize,
    pub rows: &'a mut [T],
}

/// Split `data` (row-major, `row_len` elements per row) into `parts`
/// contiguous row ranges.  Requires `parts <= rows` (guaranteed when
/// `parts` comes from [`n_tasks`]).
pub fn split_rows_mut<T>(
    data: &mut [T],
    row_len: usize,
    parts: usize,
) -> Vec<RowChunk<'_, T>> {
    let rl = row_len.max(1);
    let rows = data.len() / rl;
    let base = rows / parts;
    let extra = rows % parts;
    let mut out = Vec::with_capacity(parts);
    let mut rest = data;
    let mut row0 = 0usize;
    for p in 0..parts {
        let take_rows = base + usize::from(p < extra);
        let (head, tail) =
            std::mem::take(&mut rest).split_at_mut(take_rows * rl);
        out.push(RowChunk { row0, rows: head });
        rest = tail;
        row0 += take_rows;
    }
    out
}

/// Row-parallel driver: split `data` into at most [`threads`] contiguous
/// row ranges (each with at least `grain` rows) and run
/// `f(first_row_index, range)` on each.  `f` must derive everything it
/// writes from `first_row_index` and shared immutable state, which makes
/// the result independent of the split.
pub fn for_rows<T, F>(data: &mut [T], row_len: usize, grain: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = data.len() / row_len.max(1);
    let parts = n_tasks(rows, grain);
    if parts <= 1 {
        f(0, data);
        return;
    }
    let fref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
        split_rows_mut(data, row_len, parts)
            .into_iter()
            .map(|c| {
                Box::new(move || fref(c.row0, c.rows))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
    run_tasks(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_all_rows_contiguously() {
        let mut v: Vec<u32> = (0..23 * 3).collect();
        let chunks = split_rows_mut(&mut v, 3, 4);
        assert_eq!(chunks.len(), 4);
        let mut next = 0usize;
        let mut total = 0usize;
        for c in &chunks {
            assert_eq!(c.row0, next);
            assert_eq!(c.rows.len() % 3, 0);
            assert_eq!(c.rows[0], (c.row0 * 3) as u32);
            next += c.rows.len() / 3;
            total += c.rows.len();
        }
        assert_eq!(next, 23);
        assert_eq!(total, 23 * 3);
    }

    #[test]
    fn n_tasks_respects_grain_and_threads() {
        assert_eq!(n_tasks(0, 8), 1);
        assert_eq!(n_tasks(7, 8), 1); // below grain -> serial
        // race-free bounds only: sibling tests mutate the global thread
        // count concurrently, so never compare against a second read of
        // threads().  The items/grain quotient caps n_tasks regardless.
        assert!(n_tasks(1 << 20, 1 << 18) <= 4); // 2^20 / 2^18 = 4
        assert!(n_tasks(1 << 20, 1) >= 1);
        assert!(n_tasks(5, 1) <= 5); // never more tasks than items
    }

    #[test]
    fn for_rows_writes_every_row_once() {
        set_threads(4);
        let rows = 101usize;
        let d = 7usize;
        let mut out = vec![0.0f32; rows * d];
        for_rows(&mut out, d, 1, |r0, chunk| {
            for (ri, row) in chunk.chunks_exact_mut(d).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = ((r0 + ri) * d + j) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn run_tasks_borrows_stack_data_and_propagates_panics() {
        set_threads(4);
        let data = vec![1u64, 2, 3, 4, 5, 6, 7, 8];
        let mut partials = vec![0u64; 4];
        {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = partials
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    let d = &data;
                    Box::new(move || *slot = d[2 * i] + d[2 * i + 1])
                        as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_tasks(tasks);
        }
        assert_eq!(partials, vec![3, 7, 11, 15]);

        let caught = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                .map(|i| {
                    Box::new(move || {
                        if i == 1 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            run_tasks(tasks);
        }));
        assert!(caught.is_err(), "task panic must propagate to the caller");
        // pool still works afterwards
        let mut x = 0u32;
        run_tasks(vec![Box::new(|| x = 7)]);
        assert_eq!(x, 7);
    }
}
