//! Deterministic parallel compute core.
//!
//! Cache-blocked, multi-threaded CPU kernels for the native backend:
//! matmul/linear, layer norm, GELU and multi-head attention, each with a
//! hand-written VJP counterpart one layer up (`runtime::native::blocks`).
//!
//! ## The determinism-by-construction rule
//!
//! The paper's whole value proposition is *exact bit-level* reversibility
//! (eq. 24 reconstruction from 1 bit of side info per element), so this
//! layer obeys one invariant everywhere:
//!
//! > **Parallelism partitions output rows / examples only.**  Every output
//! > element is produced by exactly one task, and its reduction runs in
//! > the same ascending index order as the scalar reference loop.  No
//! > partial sums are ever combined across tasks.
//!
//! Consequently every result is bit-identical for any thread count
//! (`threads = 1, 2, 4, 7, ...` — asserted by `tests/determinism.rs`),
//! and the blocked loops are bit-identical to the naive triple loop
//! (tiling only regroups iterations, never reorders a reduction).
//!
//! There is also **no value-dependent control flow**: the seed
//! interpreter's `a != 0.0` skip dropped `0.0 * inf = NaN` contributions
//! and is gone — kernels are IEEE-faithful to the plain summation.
//!
//! ## Tuning
//!
//! Loop-shape knobs (k-panel size, row grain, inner-loop chunk width,
//! cached NT transpose) live in a [`profile::KernelProfile`].  Because
//! every knob only regroups or re-chunks iterations — never a reduction
//! order — **any legal profile is bit-exact by construction**: the same
//! results as the default profile, at every thread count.  `bdia tune`
//! ([`tune`]) benchmarks candidate profiles on the live pool and persists
//! the winner as JSON next to the checkpoint.
//!
//! ## Layout
//!
//! * [`pool`] — persistent `std::thread` worker pool; the `threads`
//!   config/CLI knob; row-partitioning helpers
//! * [`workspace`] — thread-local buffer arena: steady-state calls reuse
//!   scratch and output buffers instead of allocating; keyed cache for
//!   static-weight transposes
//! * [`profile`] — versioned per-shape kernel parameter profiles, the
//!   process-wide active profile, JSON persistence
//! * [`tune`] — candidate search that produces a [`profile::KernelProfile`]
//! * [`matmul`] — blocked matmul / linear / transposed variants
//! * [`norm`] — layer norm forward/backward
//! * [`elementwise`] — add / column sums / GELU maps / the `axpy`
//!   microkernel behind every inner loop
//! * [`attention`] — multi-head attention forward/backward, parallel
//!   across (batch, head) pairs

pub mod attention;
pub mod elementwise;
pub mod matmul;
pub mod norm;
pub mod pool;
pub mod profile;
pub mod tune;
pub mod workspace;

pub use attention::{attn_bwd, attn_decode, attn_fwd, AttnCache, AttnGrads, AttnW, NEG_INF};
pub use elementwise::{
    add, add_into, axpy, col_sum, gelu, gelu_grad, map_gelu,
    scale_by_gelu_grad,
};
pub use matmul::{linear, matmul, matmul_nt, matmul_nt_w, matmul_tn};
pub use norm::{ln_bwd, ln_fwd, LnCache};
pub use profile::{KernelProfile, OpKind, OpParams};

/// Rows per task for a row-parallel loop whose per-row cost is roughly
/// `work_per_row` flops, driven by the active profile's grain budget.
/// The unified heuristic behind matmul, norm and elementwise splits:
/// under the default profile it reproduces the historical constants
/// (`GRAIN_FLOP = 1 << 14`, `MAP_GRAIN = 1 << 12`, ...) bit-for-bit.
pub fn grain(work_per_row: usize) -> usize {
    profile::grain_of(profile::grain_flop(), work_per_row)
}
