//! Fleet integration over real sockets: a router fanning requests across
//! in-process replica threads must answer bit-identically to direct
//! inference, survive a replica dying mid-load (evict + re-dispatch the
//! un-acked batch, then re-admit a newcomer), and bounce requests with a
//! prompt `503 Retry-After` once the admission queue saturates.

use bdia::config::json::Json;
use bdia::fleet::replica::serve_connection;
use bdia::fleet::{FleetConfig, Router};
use bdia::model::ParamStore;
use bdia::runtime::Runtime;
use bdia::serve::wire::Example;
use bdia::serve::{client, http, wire};
use std::io::Read;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;
use std::time::Duration;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Local reference runtime + the same seed-0 params the ckpt-less router
/// initializes (and pushes to every replica in `FLEET_WELCOME`).
fn reference(model: &str) -> (Runtime, ParamStore) {
    let rt = Runtime::load(&artifacts(), model).unwrap();
    let params = ParamStore::init(&rt.manifest, 0);
    (rt, params)
}

fn start_router(model: &str, queue_cap: usize) -> Router {
    let (rt, params) = reference(model);
    Router::start_with_parts(
        FleetConfig {
            model: model.into(),
            artifacts_dir: artifacts(),
            port: 0,
            batch_window: Duration::from_millis(5),
            queue_cap,
            deadline: Duration::from_secs(2),
            ..FleetConfig::default()
        },
        rt,
        params,
        std::sync::Arc::new(bdia::api::NullSink),
    )
    .expect("router start")
}

/// Run one replica as an in-process thread (no child process needed):
/// its own runtime, a real TCP connection to the router's backplane.
fn spawn_replica(
    router: &Router,
    model: &'static str,
    die_after_batches: Option<usize>,
) -> JoinHandle<()> {
    let backplane = router.backplane_addr();
    std::thread::spawn(move || {
        let rt = Runtime::load(&artifacts(), model).unwrap();
        let stream = TcpStream::connect(backplane).unwrap();
        serve_connection(stream, &rt, Duration::from_secs(2), die_after_batches)
            .unwrap();
    })
}

fn gpt_example(i: usize, seq: usize, vocab: usize) -> Example {
    let tokens: Vec<i32> =
        (0..seq).map(|j| ((i * 7 + j * 3 + 1) % vocab) as i32).collect();
    let labels: Vec<i32> =
        (0..seq).map(|j| ((i * 5 + j * 2 + 2) % vocab) as i32).collect();
    Example::Tok { tokens, labels }
}

#[test]
fn fleet_round_trip_bit_exact_across_replicas() {
    let (rt, params) = reference("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let router = start_router("smoke_gpt", 0);
    let addr = router.addr();
    let replicas: Vec<_> =
        (0..2).map(|_| spawn_replica(&router, "smoke_gpt", None)).collect();
    router.wait_ready(2, Duration::from_secs(30)).unwrap();

    // concurrent mixed-γ load: sticky batching must keep γ keys apart,
    // and every response must land on the request that sent it
    let n = 16usize;
    let examples: Vec<Example> =
        (0..n).map(|i| gpt_example(i, dims.seq, dims.vocab)).collect();
    let gammas: Vec<f32> =
        (0..n).map(|i| if i % 2 == 0 { 0.0 } else { 0.5 }).collect();
    let expected: Vec<(f32, f32)> = examples
        .iter()
        .zip(&gammas)
        .map(|(e, g)| wire::infer_one(&rt, &params, e, *g).unwrap())
        .collect();
    let handles: Vec<_> = examples
        .iter()
        .zip(&gammas)
        .map(|(e, g)| {
            let body = wire::encode(e, *g);
            std::thread::spawn(move || client::infer(addr, &body).unwrap())
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&expected) {
        let (loss, correct) = h.join().unwrap();
        assert_eq!(
            loss.to_bits(),
            want.0.to_bits(),
            "fleet-served loss differs from direct model_infer_ex"
        );
        assert_eq!(correct.to_bits(), want.1.to_bits());
    }

    let (status, body) = client::get(addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    let health = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(
        health.get("replicas_live").unwrap().as_usize().unwrap(),
        2
    );

    // fleet /stats totals must equal the sum of per-replica counts
    let (status, body) = client::get(addr, "/stats").unwrap();
    assert_eq!(status, 200);
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), n);
    assert_eq!(stats.get("errors").unwrap().as_usize().unwrap(), 0);
    let per_replica = stats
        .get("replicas")
        .unwrap()
        .get("per_replica")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(per_replica.len(), 2);
    let summed: usize = per_replica
        .iter()
        .map(|r| r.get("requests").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(summed, n, "router total != sum of per-replica requests");

    client::shutdown(addr).unwrap();
    router.join().unwrap();
    for r in replicas {
        r.join().unwrap(); // replicas exit cleanly on FLEET_GOODBYE
    }
}

#[test]
fn replica_death_mid_load_evicts_and_redispatches() {
    let (rt, params) = reference("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let router = start_router("smoke_gpt", 0);
    let addr = router.addr();

    // replica 0 drops its connection on the FIRST batch without acking;
    // admit it first so the least-outstanding tie-break (lowest id) is
    // guaranteed to hand it that batch
    let doomed = spawn_replica(&router, "smoke_gpt", Some(0));
    router.wait_ready(1, Duration::from_secs(30)).unwrap();
    let healthy = spawn_replica(&router, "smoke_gpt", None);
    router.wait_ready(2, Duration::from_secs(30)).unwrap();

    let n = 8usize;
    let examples: Vec<Example> =
        (0..n).map(|i| gpt_example(i, dims.seq, dims.vocab)).collect();
    let expected: Vec<(f32, f32)> = examples
        .iter()
        .map(|e| wire::infer_one(&rt, &params, e, 0.0).unwrap())
        .collect();
    let handles: Vec<_> = examples
        .iter()
        .map(|e| {
            let body = wire::encode(e, 0.0);
            std::thread::spawn(move || client::infer(addr, &body).unwrap())
        })
        .collect();
    // every request succeeds — the un-acked batch was re-dispatched to
    // the survivor, and the re-run answer is bit-identical
    for (h, want) in handles.into_iter().zip(&expected) {
        let (loss, correct) = h.join().unwrap();
        assert_eq!(loss.to_bits(), want.0.to_bits());
        assert_eq!(correct.to_bits(), want.1.to_bits());
    }
    doomed.join().unwrap();

    let (_, body) = client::get(addr, "/healthz").unwrap();
    let health = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(health.get("replicas_live").unwrap().as_usize().unwrap(), 1);
    assert_eq!(
        health.get("replicas_evicted").unwrap().as_usize().unwrap(),
        1
    );

    let (_, body) = client::get(addr, "/stats").unwrap();
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), n);
    assert_eq!(stats.get("evictions").unwrap().as_usize().unwrap(), 1);
    assert!(
        stats.get("redispatched").unwrap().as_usize().unwrap() >= 1,
        "the dead replica's batch must be re-dispatched, not dropped"
    );

    // re-admission: a fresh replica joins the running fleet
    let late = spawn_replica(&router, "smoke_gpt", None);
    router.wait_ready(2, Duration::from_secs(30)).unwrap();

    client::shutdown(addr).unwrap();
    router.join().unwrap();
    healthy.join().unwrap();
    late.join().unwrap();
}

#[test]
fn saturation_gets_prompt_503_with_retry_after() {
    // tiny admission cap, ZERO replicas: the dispatcher parks the first
    // micro-batch waiting for a replica, the queue fills behind it, and
    // further requests must bounce immediately instead of queueing
    let (rt, _) = reference("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let router = start_router("smoke_gpt", 2);
    let addr = router.addr();

    // background requests that will sit in (and overflow) the queue;
    // detached on purpose — they resolve as 500s at shutdown
    for i in 0..6usize {
        let body = wire::encode(&gpt_example(i, dims.seq, dims.vocab), 0.0);
        std::thread::spawn(move || {
            let _ = client::infer(addr, &body);
        });
    }
    std::thread::sleep(Duration::from_millis(300));

    // probe with a raw stream so the Retry-After HEADER is visible
    let body = wire::encode(&gpt_example(99, dims.seq, dims.vocab), 0.0);
    let mut saw_503 = false;
    for _ in 0..40 {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(2))).ok();
        http::write_request(&stream, "POST", "/infer", &body).unwrap();
        let mut bytes = Vec::new();
        // a probe that slipped into the queue times out here and is
        // abandoned (its slot keeps the queue full for the next probe)
        let _ = (&stream).read_to_end(&mut bytes);
        let raw = String::from_utf8_lossy(&bytes);
        if raw.contains("503") {
            assert!(
                raw.contains("Retry-After:"),
                "503 without Retry-After header:\n{raw}"
            );
            assert!(
                raw.contains("queue_cap"),
                "503 body must name the cap:\n{raw}"
            );
            assert!(
                raw.contains("queue_depth"),
                "503 body must name the depth:\n{raw}"
            );
            saw_503 = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(saw_503, "saturated queue never produced a 503");

    // shutdown drains the parked jobs as errors — no hang
    router.shutdown().unwrap();
}
