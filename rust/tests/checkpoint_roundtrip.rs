//! Checkpoint persistence integration: bit-exact save→load→re-save round
//! trips across all three model families, rejection of truncated and
//! corrupted files, cross-model guards, and — the strongest property —
//! resume-equivalence: train K steps, checkpoint, reload into a fresh
//! trainer, continue, and land on parameters bit-identical to an
//! uninterrupted run.

use bdia::checkpoint::{self, CheckpointRef};
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use bdia::model::ParamStore;
use std::path::{Path, PathBuf};

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg_for(bundle: &str) -> TrainConfig {
    TrainConfig {
        model: bundle.into(),
        mode: TrainMode::BdiaReversible,
        dataset: match bundle {
            "smoke_vit" => "synth_cifar10".into(),
            "smoke_gpt" => "tiny_corpus".into(),
            "smoke_encdec" => "synth_translation".into(),
            _ => unreachable!(),
        },
        steps: 4,
        eval_every: 0,
        log_every: 1,
        artifacts_dir: artifacts(),
        train_examples: 64,
        val_examples: 16,
        lr: 1e-3,
        ..TrainConfig::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("bdia_ckpt_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Flatten every parameter to its raw bit pattern (exact comparison).
fn store_bits(ps: &ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

#[test]
fn roundtrip_bit_exact_across_families() {
    let dir = tmp_dir("families");
    for bundle in ["smoke_vit", "smoke_gpt", "smoke_encdec"] {
        let cfg = cfg_for(bundle);
        let mut tr = Trainer::new(cfg.clone()).unwrap();
        let ds = dataset_for(&tr.rt, &cfg).unwrap();
        // a couple of real steps so params/moments are nontrivial
        for step in 0..2 {
            tr.train_step(&ds.train_batch(step)).unwrap();
        }
        let p1 = dir.join(format!("{bundle}.ckpt"));
        tr.save_checkpoint(&p1).unwrap();

        // load: params bit-identical to the in-memory trainer
        let ck = checkpoint::load(&p1).unwrap();
        assert_eq!(ck.model, bundle);
        assert_eq!(ck.step, 2);
        assert_eq!(
            store_bits(&ck.params),
            store_bits(&tr.params),
            "{bundle}: params not bit-exact after round trip"
        );
        let opt = ck.opt.as_ref().expect("training checkpoint carries opt");
        assert_eq!(opt.t, 2);

        // re-save of the loaded state is byte-identical (canonical format)
        let p2 = dir.join(format!("{bundle}.resave.ckpt"));
        checkpoint::save(
            &p2,
            &CheckpointRef {
                model: &ck.model,
                step: ck.step,
                rng_gamma: ck.rng_gamma,
                params: &ck.params,
                opt: ck.opt.as_ref().map(|o| (o.t, &o.m, &o.v)),
            },
        )
        .unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "{bundle}: re-save is not byte-identical"
        );

        // a fresh trainer restores to the same bits and the same eval
        let mut tr2 = Trainer::new(cfg.clone()).unwrap();
        assert_ne!(store_bits(&tr2.params), store_bits(&tr.params));
        tr2.load_checkpoint(&p1).unwrap();
        assert_eq!(store_bits(&tr2.params), store_bits(&tr.params));
        assert_eq!(tr2.step(), 2);
        let (l1, a1) = tr.evaluate(ds.as_ref(), 2, 0.0).unwrap();
        let (l2, a2) = tr2.evaluate(ds.as_ref(), 2, 0.0).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits(), "{bundle}: eval loss differs");
        assert_eq!(a1.to_bits(), a2.to_bits());
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_matches_uninterrupted_run_bit_exactly() {
    let dir = tmp_dir("resume");
    let cfg = cfg_for("smoke_gpt");

    // uninterrupted: 4 steps straight
    let mut a = Trainer::new(cfg.clone()).unwrap();
    let ds = dataset_for(&a.rt, &cfg).unwrap();
    for step in 0..4 {
        a.train_step(&ds.train_batch(step)).unwrap();
    }

    // interrupted: 2 steps, checkpoint, fresh process, 2 more
    let mut b1 = Trainer::new(cfg.clone()).unwrap();
    for step in 0..2 {
        b1.train_step(&ds.train_batch(step)).unwrap();
    }
    let ckpt = dir.join("mid.ckpt");
    b1.save_checkpoint(&ckpt).unwrap();
    drop(b1);
    let mut b2 = Trainer::new(cfg.clone()).unwrap();
    b2.load_checkpoint(&ckpt).unwrap();
    assert_eq!(b2.step(), 2);
    for step in 2..4 {
        b2.train_step(&ds.train_batch(step)).unwrap();
    }

    assert_eq!(
        store_bits(&a.params),
        store_bits(&b2.params),
        "resumed training diverged from the uninterrupted run"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn save_every_writes_stamped_and_latest_checkpoints() {
    let dir = tmp_dir("save_every");
    let mut cfg = cfg_for("smoke_gpt");
    cfg.steps = 3;
    cfg.save_every = 2;
    cfg.ckpt_dir = dir.clone();
    let mut tr = Trainer::new(cfg.clone()).unwrap();
    let ds = dataset_for(&tr.rt, &cfg).unwrap();
    tr.run(ds.as_ref(), "unit").unwrap();
    // step 2 (periodic) and step 3 (final) + rolling latest
    for f in ["unit-step2.ckpt", "unit-step3.ckpt", "unit-latest.ckpt"] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    let latest = checkpoint::load(&dir.join("unit-latest.ckpt")).unwrap();
    assert_eq!(latest.step, 3);
    assert_eq!(store_bits(&latest.params), store_bits(&tr.params));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_and_corrupted_files_are_rejected() {
    let dir = tmp_dir("damage");
    let cfg = cfg_for("smoke_gpt");
    let tr = Trainer::new(cfg).unwrap();
    let path = dir.join("ok.ckpt");
    tr.save_checkpoint(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    let truncated = dir.join("truncated.ckpt");
    std::fs::write(&truncated, &bytes[..bytes.len() / 3]).unwrap();
    let err = format!("{:#}", checkpoint::load(&truncated).unwrap_err());
    assert!(
        err.to_lowercase().contains("truncated"),
        "unexpected truncation error: {err}"
    );

    let corrupted = dir.join("corrupted.ckpt");
    let mut bad = bytes.clone();
    let mid = bytes.len() / 2;
    bad[mid] ^= 0x10;
    std::fs::write(&corrupted, &bad).unwrap();
    let err = format!("{:#}", checkpoint::load(&corrupted).unwrap_err());
    assert!(err.contains("checksum"), "unexpected corruption error: {err}");

    let noise = dir.join("noise.ckpt");
    std::fs::write(&noise, b"definitely not a checkpoint").unwrap();
    assert!(checkpoint::load(&noise).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn wrong_model_checkpoint_is_refused() {
    let dir = tmp_dir("mismatch");
    let gpt = Trainer::new(cfg_for("smoke_gpt")).unwrap();
    let path = dir.join("gpt.ckpt");
    gpt.save_checkpoint(&path).unwrap();
    let mut vit = Trainer::new(cfg_for("smoke_vit")).unwrap();
    let err = format!("{:#}", vit.load_checkpoint(&path).unwrap_err());
    assert!(
        err.contains("smoke_gpt") && err.contains("smoke_vit"),
        "error should name both models: {err}"
    );
    std::fs::remove_dir_all(dir).ok();
}
