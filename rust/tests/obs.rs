//! Observability integration over real sockets and real files: a live
//! server answers `GET /metrics` with a Prometheus exposition the in-repo
//! checker accepts (and that agrees with the legacy `/stats` JSON),
//! request ids round-trip through response headers and error bodies, and
//! per-rank Chrome trace files export + merge onto one clock.

use bdia::config::json::Json;
use bdia::obs::{prom, trace};
use bdia::runtime::Runtime;
use bdia::serve::wire::Example;
use bdia::serve::{client, http, wire, ServeConfig, Server};
use std::io::Read;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn start(model: &str, workers: usize, window: Duration) -> Server {
    Server::start(ServeConfig {
        model: model.into(),
        artifacts_dir: artifacts(),
        port: 0,
        workers,
        batch_window: window,
        ..ServeConfig::default()
    })
    .expect("server start")
}

fn gpt_example(i: usize, seq: usize, vocab: usize) -> Example {
    let tokens: Vec<i32> =
        (0..seq).map(|j| ((i * 7 + j * 3 + 1) % vocab) as i32).collect();
    let labels: Vec<i32> =
        (0..seq).map(|j| ((i * 5 + j * 2 + 2) % vocab) as i32).collect();
    Example::Tok { tokens, labels }
}

#[test]
fn metrics_endpoint_is_valid_prometheus_and_agrees_with_stats() {
    let rt = Runtime::load(&artifacts(), "smoke_gpt").unwrap();
    let d = rt.manifest.dims.clone();
    let server = start("smoke_gpt", 2, Duration::from_millis(5));
    let addr = server.addr();

    // drive a few requests so every counter family has moved
    let n = 5usize;
    for i in 0..n {
        let body = wire::encode(&gpt_example(i, d.seq, d.vocab), 0.0);
        client::infer(addr, &body).unwrap();
    }

    let (status, body) = client::get(addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let e = prom::check(&text).expect("exposition must pass the checker");
    assert!(e.families >= 5, "only {} families", e.families);
    assert!(text.contains("bdia_requests_total"), "{text}");
    assert!(text.contains("bdia_request_latency_us_bucket"), "{text}");
    assert!(
        text.contains("bdia_exec_calls_total{exec=\"model_infer_ex\"}"),
        "{text}"
    );

    // the legacy JSON and the exposition render from the same registry
    let (_, sbody) = client::get(addr, "/stats").unwrap();
    let stats = Json::parse(&String::from_utf8(sbody).unwrap()).unwrap();
    let requests = stats.get("requests").unwrap().as_usize().unwrap();
    assert_eq!(requests, n);
    assert!(
        text.contains(&format!("bdia_requests_total {requests}")),
        "/metrics and /stats disagree on requests: {text}"
    );

    client::shutdown(addr).unwrap();
    server.join().unwrap();
}

/// One raw request/response round trip so response *headers* are visible
/// (the library client discards them).
fn roundtrip(addr: SocketAddr, rid: &str, body: &[u8]) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let hdr = [("X-Request-Id", rid.to_string())];
    http::write_request_with(&stream, "POST", "/infer", &hdr, body).unwrap();
    let mut raw = Vec::new();
    (&stream).read_to_end(&mut raw).ok();
    String::from_utf8_lossy(&raw).to_string()
}

#[test]
fn request_ids_echo_through_headers_and_error_bodies() {
    let rt = Runtime::load(&artifacts(), "smoke_gpt").unwrap();
    let d = rt.manifest.dims.clone();
    let server = start("smoke_gpt", 1, Duration::from_millis(1));
    let addr = server.addr();

    // happy path: the client-supplied id comes back as a response header
    let ok_body = wire::encode(&gpt_example(0, d.seq, d.vocab), 0.0);
    let raw = roundtrip(addr, "rid-echo-42", &ok_body);
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    assert!(raw.contains("X-Request-Id: rid-echo-42"), "{raw}");

    // error path: a malformed body gets a 400 whose JSON carries the id
    let raw = roundtrip(addr, "rid-err-7", b"\x00\x01");
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    assert!(raw.contains("X-Request-Id: rid-err-7"), "{raw}");
    assert!(raw.contains("\"request_id\": \"rid-err-7\""), "{raw}");

    // no id supplied: the server mints one and still echoes it
    let stream = TcpStream::connect(addr).unwrap();
    http::write_request(&stream, "POST", "/infer", b"\x00").unwrap();
    let mut raw = Vec::new();
    (&stream).read_to_end(&mut raw).ok();
    let raw = String::from_utf8_lossy(&raw);
    assert!(raw.contains("X-Request-Id: "), "{raw}");

    client::shutdown(addr).unwrap();
    server.join().unwrap();
}

#[test]
fn per_rank_traces_export_and_merge_onto_one_clock() {
    let dir = std::env::temp_dir()
        .join(format!("bdia_obs_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // simulate two ranks in turn: same span name, different clock offsets
    bdia::obs::set_level(bdia::obs::SPANS);
    bdia::obs::reset_trace();
    bdia::obs::set_rank(0);
    bdia::obs::set_clock_offset_us(0);
    {
        let _s = bdia::span!("demo_phase", step = 1);
    }
    let p0 = dir.join("t.rank0.json");
    bdia::obs::export_chrome_trace(&p0).unwrap();

    bdia::obs::reset_trace();
    bdia::obs::set_rank(1);
    bdia::obs::set_clock_offset_us(1234);
    {
        let _s = bdia::span!("demo_phase", step = 1);
    }
    let p1 = dir.join("t.rank1.json");
    bdia::obs::export_chrome_trace(&p1).unwrap();

    bdia::obs::set_level(bdia::obs::OFF);
    bdia::obs::set_rank(0);
    bdia::obs::set_clock_offset_us(0);

    let texts = vec![
        std::fs::read_to_string(&p0).unwrap(),
        std::fs::read_to_string(&p1).unwrap(),
    ];
    let merged = trace::merge(&texts).unwrap();
    let doc = Json::parse(&merged).unwrap();
    assert_eq!(
        doc.get("metadata").unwrap().get("ranks").unwrap().as_usize().unwrap(),
        2
    );
    // the CI gate accepts spans that exist on every rank, rejects others
    trace::require_spans(&merged, &["demo_phase".to_string()]).unwrap();
    assert!(trace::require_spans(&merged, &["missing".to_string()]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
