//! Property-based tests (in-repo harness; proptest unavailable offline).
//!
//! Each property runs across hundreds of seeded random cases; a failure
//! reports the seed for replay.  These are pure-host properties — no PJRT —
//! so they run in milliseconds and cover far more cases than the
//! integration tests.

use bdia::config::json::Json;
use bdia::coordinator::GammaPlan;
use bdia::metrics::memory::MemoryModel;
use bdia::model::{Dims, Family};
use bdia::quant::{self, BitVec, Fixed};
use bdia::tensor::{Rng, Tensor};

/// Run `f(case_rng)` for `n` seeded cases; panic with the failing seed.
fn for_cases(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xabcd);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn grid_tensor(f: Fixed, shape: &[usize], rng: &mut Rng, scale: f32) -> Tensor {
    let mut t = Tensor::normal(shape, scale, rng);
    f.quantize_slice(t.data_mut());
    t
}

fn rand_signs(rng: &mut Rng, b: usize) -> Vec<i8> {
    (0..b).map(|_| rng.sign()).collect()
}

// ---------------------------------------------------------------------------
// eq. 21 <-> eq. 24 single-step properties
// ---------------------------------------------------------------------------

#[test]
fn prop_single_step_roundtrip_bit_exact() {
    for_cases(300, |rng| {
        let lbits = [7u32, 9, 11][rng.below(3)];
        let f = Fixed::new(lbits);
        let b = 1 + rng.below(4);
        let per = 1 + rng.below(64);
        // stay within the documented headroom |x| < 2^(24-l); the guard
        // behaviour above it is tested separately below
        let max_scale = (quant::UNIT_HEADROOM as f64 * f.step() / 16.0) as f32;
        let scale = [0.5f32, 2.0, 50.0, max_scale][rng.below(4)];
        let xp = grid_tensor(f, &[b, per], rng, scale);
        let x = grid_tensor(f, &[b, per], rng, scale);
        let h = Tensor::normal(&[b, per], scale, rng);
        let signs = rand_signs(rng, b);
        let (xn, bits) = quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
        let rec = quant::bdia_reconstruct_quant(&xn, &x, &h, &bits, &signs, f).unwrap();
        assert_eq!(rec.data(), xp.data());
    });
}

#[test]
fn prop_headroom_overflow_fails_loudly_not_silently() {
    // beyond 2^(24-l) the f32 grid drops bits; the combine must error, never
    // return silently-wrong values (regression for the case the roundtrip
    // property originally caught at lbits=11, scale=2000).
    let f = Fixed::new(11);
    let huge = (quant::UNIT_HEADROOM as f64 * f.step()) as f32 * 0.9;
    let xp = Tensor::from_vec(&[1, 2], vec![f.quantize(huge), 0.0]).unwrap();
    let x = Tensor::from_vec(&[1, 2], vec![f.quantize(huge), 0.0]).unwrap();
    let h = Tensor::from_vec(&[1, 2], vec![huge, 0.0]).unwrap();
    let res = quant::bdia_forward_quant(&xp, &x, &h, &[1], f);
    assert!(res.is_err(), "overflow must be a hard error");
}

#[test]
fn prop_forward_output_always_on_grid() {
    for_cases(200, |rng| {
        let f = Fixed::new(9);
        let b = 1 + rng.below(3);
        let xp = grid_tensor(f, &[b, 32], rng, 3.0);
        let x = grid_tensor(f, &[b, 32], rng, 3.0);
        let h = Tensor::normal(&[b, 32], 1.5, rng);
        let signs = rand_signs(rng, b);
        let (xn, _) = quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
        for &v in xn.data() {
            assert!(f.is_on_grid(v), "off-grid output {v}");
        }
    });
}

#[test]
fn prop_side_bits_equal_parity() {
    for_cases(200, |rng| {
        let f = Fixed::new(9);
        let xp = grid_tensor(f, &[2, 16], rng, 4.0);
        let x = grid_tensor(f, &[2, 16], rng, 4.0);
        let h = Tensor::normal(&[2, 16], 1.0, rng);
        let signs = rand_signs(rng, 2);
        let (_, bits) = quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
        for (i, &v) in xp.data().iter().enumerate() {
            let n = f.units_of_exact(v).unwrap();
            assert_eq!(bits.get(i), Fixed::parity_units(n) == 1);
        }
    });
}

// ---------------------------------------------------------------------------
// multi-step chain: depth does not accumulate error (the paper's whole point)
// ---------------------------------------------------------------------------

#[test]
fn prop_deep_chain_roundtrip_exact_any_depth() {
    // Simulate a K-deep BDIA stack with random residuals h_k (no HLO): the
    // quantized inversion must be exact at EVERY depth, unlike Fig. 2.
    for_cases(60, |rng| {
        let f = Fixed::new(9);
        let k_total = 2 + rng.below(63); // up to 64 "blocks"
        let b = 1 + rng.below(3);
        let per = 8 + rng.below(24);
        let x0 = grid_tensor(f, &[b, per], rng, 2.0);
        let h: Vec<Tensor> = (0..k_total)
            .map(|_| Tensor::normal(&[b, per], 1.0, rng))
            .collect();
        let signs: Vec<Vec<i8>> = (0..k_total).map(|_| rand_signs(rng, b)).collect();

        // forward chain (eqs. 19, 21), recording everything
        let x1 = quant::first_step_quant(&x0, &h[0], f).unwrap();
        let mut xs = vec![x0, x1];
        let mut side = Vec::new();
        for k in 1..k_total {
            let (nx, bits) =
                quant::bdia_forward_quant(&xs[k - 1], &xs[k], &h[k], &signs[k], f)
                    .unwrap();
            xs.push(nx);
            side.push(bits);
        }

        // backward walk using ONLY the top two + side info
        let mut x_next = xs[k_total].clone();
        let mut x_cur = xs[k_total - 1].clone();
        for k in (1..k_total).rev() {
            let rec = quant::bdia_reconstruct_quant(
                &x_next, &x_cur, &h[k], &side[k - 1], &signs[k], f,
            )
            .unwrap();
            assert_eq!(rec.data(), xs[k - 1].data(), "drift at depth {k}");
            x_next = x_cur;
            x_cur = rec;
        }
    });
}

#[test]
fn prop_float_chain_drifts_quant_chain_does_not() {
    // deep float inversion accumulates error in f32 while quant stays exact
    for_cases(20, |rng| {
        let k_total = 24;
        let b = 2;
        let per = 16;
        let f = Fixed::new(9);
        let gammas: Vec<Vec<f32>> = (0..k_total)
            .map(|_| (0..b).map(|_| 0.5 * rng.sign() as f32).collect())
            .collect();
        let h: Vec<Tensor> = (0..k_total)
            .map(|_| Tensor::normal(&[b, per], 1.0, rng))
            .collect();

        // float chain
        let x0 = Tensor::normal(&[b, per], 1.0, rng);
        let mut x1 = x0.clone();
        x1.add_assign(&h[0]).unwrap();
        let mut xs = vec![x0, x1];
        for k in 1..k_total {
            xs.push(
                quant::bdia_forward_float(&xs[k - 1], &xs[k], &h[k], &gammas[k])
                    .unwrap(),
            );
        }
        let mut x_next = xs[k_total].clone();
        let mut x_cur = xs[k_total - 1].clone();
        let mut max_drift = 0f32;
        for k in (1..k_total).rev() {
            let rec =
                quant::bdia_invert_float(&x_next, &x_cur, &h[k], &gammas[k]).unwrap();
            max_drift = max_drift.max(rec.max_abs_diff(&xs[k - 1]).unwrap());
            x_next = x_cur;
            x_cur = rec;
        }
        // f32 eq.-16 inversion over 24 blocks essentially always drifts;
        // (the quantized counterpart is asserted exactly 0 in the test above)
        assert!(max_drift > 0.0, "float chain unexpectedly exact");
    });
}

// ---------------------------------------------------------------------------
// side-info corruption: every flipped bit changes exactly one element by one
// grid step (failure-injection semantics)
// ---------------------------------------------------------------------------

#[test]
fn prop_bit_flip_shifts_one_element_one_step() {
    for_cases(100, |rng| {
        let f = Fixed::new(9);
        let b = 1 + rng.below(2);
        let per = 8 + rng.below(16);
        let xp = grid_tensor(f, &[b, per], rng, 2.0);
        let x = grid_tensor(f, &[b, per], rng, 2.0);
        let h = Tensor::normal(&[b, per], 1.0, rng);
        let signs = rand_signs(rng, b);
        let (xn, mut bits) = quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
        let victim = rng.below(b * per);
        bits.flip(victim);
        let rec = quant::bdia_reconstruct_quant(&xn, &x, &h, &bits, &signs, f).unwrap();
        for i in 0..b * per {
            let diff = (rec.data()[i] - xp.data()[i]).abs();
            if i == victim {
                assert_eq!(diff, f.step() as f32, "victim must shift one step");
            } else {
                assert_eq!(diff, 0.0, "non-victim {i} changed");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// BitVec / JSON / GammaPlan / memory-model properties
// ---------------------------------------------------------------------------

#[test]
fn prop_bitvec_roundtrip_random_patterns() {
    for_cases(200, |rng| {
        let len = 1 + rng.below(300);
        let pattern: Vec<u8> = (0..len).map(|_| (rng.below(2)) as u8).collect();
        let bv = BitVec::from_parities(pattern.iter().copied());
        assert_eq!(bv.len(), len);
        let ones = pattern.iter().filter(|&&p| p == 1).count();
        assert_eq!(bv.count_ones(), ones);
        for (i, &p) in pattern.iter().enumerate() {
            assert_eq!(bv.get(i), p == 1);
        }
    });
}

#[test]
fn prop_json_display_parse_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0) as f64),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from(b' ' + rng.below(94) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_cases(300, |rng| {
        let j = gen(rng, 3);
        let text = j.to_string();
        let j2 = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        match (&j, &j2) {
            (Json::Num(a), Json::Num(b)) => assert!((a - b).abs() < 1e-9),
            _ => assert_eq!(j, j2, "text: {text}"),
        }
    });
}

#[test]
fn prop_gamma_plan_draw_is_balanced_and_block0_zero() {
    let mut rng = Rng::new(0);
    let plan = GammaPlan::draw(&mut rng, 8, 4096, 0.5);
    assert!(plan.gammas[0].iter().all(|&g| g == 0.0), "block 0 has no BDIA");
    for k in 1..8 {
        let pos = plan.gammas[k].iter().filter(|&&g| g > 0.0).count();
        let frac = pos as f64 / 4096.0;
        assert!((frac - 0.5).abs() < 0.05, "block {k} biased: {frac}");
        assert!(plan.gammas[k].iter().all(|&g| g.abs() == 0.5));
    }
    // signs() contract
    assert!(plan.signs(1).is_ok());
    let bad = GammaPlan::constant(4, 2, 0.3);
    assert!(bad.signs(1).is_err(), "non-half gamma must be rejected");
    let zero = GammaPlan::draw(&mut rng, 4, 8, 0.0);
    assert!(zero.gammas.iter().flatten().all(|&g| g == 0.0));
}

#[test]
fn prop_memory_model_scaling_laws() {
    let base = Dims {
        d_model: 64,
        n_heads: 4,
        n_blocks: 6,
        n_enc_blocks: 0,
        mlp_ratio: 2,
        batch: 32,
        lbits: 9,
        image_size: 32,
        patch: 4,
        channels: 3,
        n_classes: 10,
        seq: 0,
        seq_src: 0,
        vocab: 0,
    };
    use bdia::config::TrainMode;
    for k in [2usize, 4, 8, 16, 32, 64] {
        let d = Dims { n_blocks: k, ..base.clone() };
        let van = MemoryModel::new(TrainMode::Vanilla, Family::Vit, &d, 0);
        let rev = MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &d, 0);
        // vanilla activations grow linearly in depth ...
        assert!(van.stored_activations() > k * van.stored_activations() / (k + 1));
        // ... reversible boundary storage is depth-independent
        assert_eq!(
            rev.stored_activations(),
            MemoryModel::new(TrainMode::BdiaReversible, Family::Vit, &base, 0)
                .stored_activations()
        );
        // side info is the only depth-linear reversible term, at 1/32 the
        // f32 activation rate
        assert!(rev.side_info() < van.stored_activations() / 8);
    }
}

#[test]
fn prop_scale_axpy_rows_agree_with_naive() {
    for_cases(100, |rng| {
        let b = 1 + rng.below(5);
        let per = 1 + rng.below(40);
        let t = Tensor::normal(&[b, per], 1.0, rng);
        let coeffs: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
        let scaled = quant::scale_rows(&t, &coeffs).unwrap();
        let mut acc = Tensor::normal(&[b, per], 1.0, rng);
        let acc0 = acc.clone();
        quant::axpy_rows(&mut acc, &coeffs, &t).unwrap();
        for bi in 0..b {
            for i in 0..per {
                let idx = bi * per + i;
                assert_eq!(scaled.data()[idx], coeffs[bi] * t.data()[idx]);
                assert_eq!(
                    acc.data()[idx],
                    acc0.data()[idx] + coeffs[bi] * t.data()[idx]
                );
            }
        }
    });
}
