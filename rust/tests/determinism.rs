//! Cross-thread-count bit-determinism: the acceptance contract of the
//! parallel compute core.
//!
//! For every model family, training (losses, accuracies, gradients,
//! post-step parameters, gamma-RNG state) and fused quantized inference
//! (`model_infer` / `model_infer_ex` outputs) must be **bit-identical**
//! across `threads = 1, 2, 4, 7`.  The kernels guarantee this by
//! construction — row-partitioned parallelism with fixed per-element
//! reduction order — and this suite is the executable proof.
//!
//! Each signature run rebuilds the trainer from the same seed, so the only
//! degree of freedom between runs is the pool configuration.

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::data::Dataset;
use bdia::kernels::pool;
use bdia::runtime::ArgValue;

/// Everything observable from a short training run + inference, as bits.
#[derive(PartialEq)]
struct Signature {
    losses: Vec<u32>,
    grad_norms: Vec<u32>,
    params: Vec<u32>,
    grads: Vec<u32>,
    infer: Vec<u32>,
    infer_ex: Vec<u32>,
}

fn bits_of_store(ps: &bdia::model::ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

fn signature(model: &str, dataset: &str, threads: usize) -> Signature {
    pool::set_threads(threads);
    let cfg = TrainConfig {
        model: model.into(),
        mode: TrainMode::BdiaReversible,
        dataset: dataset.into(),
        steps: 2,
        eval_every: 0,
        log_every: 1,
        train_examples: 32,
        val_examples: 8,
        seed: 42,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg.clone()).expect("trainer");
    let ds = bdia::experiments::dataset_for(&tr.rt, &cfg).expect("dataset");

    let mut losses = Vec::new();
    let mut grad_norms = Vec::new();
    for step in 0..cfg.steps {
        let b = ds.train_batch(step);
        let s = tr.train_step(&b).expect("train_step");
        losses.push(s.loss.to_bits());
        grad_norms.push(s.grad_norm.to_bits());
    }
    let params = bits_of_store(&tr.params);
    let grads = bits_of_store(tr.grads());

    // fused quantized inference, scalar and per-example, gamma 0 and 0.5
    let mut infer = Vec::new();
    let mut infer_ex = Vec::new();
    for gamma in [0.0f32, 0.5] {
        for (exec, sink) in
            [("model_infer", &mut infer), ("model_infer_ex", &mut infer_ex)]
        {
            let e = tr.rt.exec(exec).expect("exec");
            let refs = tr.params.refs_for(&e.spec, 0).expect("refs");
            let batch = ds.val_batch(0);
            let outs = match &batch {
                bdia::data::Batch::Image { images, labels } => e.call(
                    &refs,
                    &[
                        ArgValue::F32(images),
                        ArgValue::I32(labels),
                        ArgValue::Scalar(gamma),
                    ],
                ),
                bdia::data::Batch::Lm { tokens, labels } => e.call(
                    &refs,
                    &[
                        ArgValue::I32(tokens),
                        ArgValue::I32(labels),
                        ArgValue::Scalar(gamma),
                    ],
                ),
                bdia::data::Batch::Seq2Seq { src, tgt_in, labels } => e.call(
                    &refs,
                    &[
                        ArgValue::I32(src),
                        ArgValue::I32(tgt_in),
                        ArgValue::I32(labels),
                        ArgValue::Scalar(gamma),
                    ],
                ),
            }
            .expect("infer call");
            for t in &outs {
                sink.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }

    Signature { losses, grad_norms, params, grads, infer, infer_ex }
}

fn assert_family_invariant(model: &str, dataset: &str) {
    let base = signature(model, dataset, 1);
    assert!(!base.params.is_empty() && !base.infer.is_empty());
    for threads in [2usize, 4, 7] {
        let sig = signature(model, dataset, threads);
        assert_eq!(
            base.losses, sig.losses,
            "{model}: training losses drifted at {threads} threads"
        );
        assert_eq!(
            base.grad_norms, sig.grad_norms,
            "{model}: gradient norms drifted at {threads} threads"
        );
        assert!(
            base.grads == sig.grads,
            "{model}: gradients drifted at {threads} threads"
        );
        assert!(
            base.params == sig.params,
            "{model}: post-step parameters drifted at {threads} threads"
        );
        assert_eq!(
            base.infer, sig.infer,
            "{model}: model_infer output drifted at {threads} threads"
        );
        assert_eq!(
            base.infer_ex, sig.infer_ex,
            "{model}: model_infer_ex output drifted at {threads} threads"
        );
    }
    pool::set_threads(0);
}

#[test]
fn vit_training_and_inference_bit_identical_across_thread_counts() {
    assert_family_invariant("smoke_vit", "synth_cifar10");
}

#[test]
fn gpt_training_and_inference_bit_identical_across_thread_counts() {
    assert_family_invariant("smoke_gpt", "tiny_corpus");
}

#[test]
fn encdec_training_and_inference_bit_identical_across_thread_counts() {
    assert_family_invariant("smoke_encdec", "synth_translation");
}

#[test]
fn tuned_profile_training_and_inference_bit_identical_across_thread_counts() {
    use bdia::kernels::profile::{reset_active, set_active};
    use bdia::kernels::{KernelProfile, OpParams};
    // a deliberately non-default profile — every knob moved off its default
    // value, nt transpose caching on.  The determinism contract says tuning
    // may only change wall time, never bytes: the full training + inference
    // signature must equal the default-profile single-thread baseline.
    let tuned = KernelProfile {
        id: "determinism-tuned".into(),
        default_params: OpParams { kc: 48, grain_flop: 1 << 12, unroll: 8, nt_cache: true },
        ..KernelProfile::default()
    };
    for (model, dataset) in [
        ("smoke_vit", "synth_cifar10"),
        ("smoke_gpt", "tiny_corpus"),
        ("smoke_encdec", "synth_translation"),
    ] {
        reset_active();
        let base = signature(model, dataset, 1);
        for threads in [1usize, 2, 4, 7] {
            set_active(tuned.clone(), None);
            let sig = signature(model, dataset, threads);
            reset_active();
            assert!(
                base == sig,
                "{model}: tuned kernel profile changed bits at {threads} threads"
            );
        }
    }
    pool::set_threads(0);
}

#[test]
fn full_tracing_and_metrics_change_no_bytes() {
    // observability is non-interfering by construction — timestamps flow
    // into histograms and the span ring, never into compute.  Prove it:
    // the full training + inference signature with span tracing and
    // metrics fully enabled must bit-match the tracing-off baseline.
    bdia::obs::set_level(bdia::obs::OFF);
    let base = signature("smoke_gpt", "tiny_corpus", 2);
    bdia::obs::set_level(bdia::obs::SPANS);
    let traced = signature("smoke_gpt", "tiny_corpus", 2);
    let (events, _dropped) = bdia::obs::snapshot();
    bdia::obs::set_level(bdia::obs::OFF);
    assert!(!events.is_empty(), "SPANS level recorded no spans");
    assert!(
        base == traced,
        "smoke_gpt: enabling tracing+metrics changed bytes"
    );
    pool::set_threads(0);
}

#[test]
fn larger_shapes_engage_the_pool_and_stay_bit_identical() {
    // the smoke bundles are small enough that some kernels stay serial;
    // vit_s10 (batch 64, 65 tokens, d 64) actually fans out.  One forward +
    // backward + infer is enough — just prove the parallel path bit-matches.
    let run = |threads: usize| -> (u32, Vec<u32>) {
        pool::set_threads(threads);
        let cfg = TrainConfig {
            model: "vit_s10".into(),
            mode: TrainMode::BdiaReversible,
            dataset: "synth_cifar10".into(),
            steps: 1,
            eval_every: 0,
            train_examples: 64,
            val_examples: 64,
            seed: 7,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg.clone()).unwrap();
        let ds = bdia::experiments::dataset_for(&tr.rt, &cfg).unwrap();
        let s = tr.train_step(&ds.train_batch(0)).unwrap();
        (s.loss.to_bits(), bits_of_store(tr.grads()))
    };
    let (loss1, grads1) = run(1);
    let (loss4, grads4) = run(4);
    assert_eq!(loss1, loss4, "vit_s10 loss drifted under the pool");
    assert!(grads1 == grads4, "vit_s10 grads drifted under the pool");
    pool::set_threads(0);
}
