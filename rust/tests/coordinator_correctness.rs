//! System-level correctness of the BDIA coordinator — the paper's claims:
//!
//! 1. **Exact bit-level reversibility** (§4.3): activations reconstructed by
//!    eq. 24 during online backprop equal the forward activations *bitwise*.
//! 2. **Gradient equivalence**: online (reconstructing) backward produces
//!    the same gradients as a store-all backward over the same quantized
//!    forward — reconstruction adds zero gradient drift.
//! 3. Float inversion (eq. 16) drifts and the drift *grows with depth*
//!    (Fig. 2's phenomenon), while the quantized path is drift-free.
//! 4. Training works end-to-end for all three families + RevViT baseline.
//!
//! Runs on the native backend: the smoke bundles are synthesized from the
//! in-crate registry, so no artifacts are needed.

use bdia::baseline::RevVitTrainer;
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::{GammaPlan, Stack, StackKind, StackState, Trainer};
use bdia::data::make_dataset;
use bdia::model::ParamStore;
use bdia::quant;
use bdia::runtime::Runtime;
use bdia::tensor::{Rng, Tensor};
use std::path::{Path, PathBuf};

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load(bundle: &str) -> Runtime {
    Runtime::load(&artifacts(), bundle).expect("native bundle")
}

fn cfg_for(bundle: &str, mode: TrainMode) -> TrainConfig {
    TrainConfig {
        model: bundle.into(),
        mode,
        dataset: match bundle {
            "smoke_vit" => "synth_cifar10".into(),
            "smoke_gpt" => "tiny_corpus".into(),
            "smoke_encdec" => "synth_translation".into(),
            _ => unreachable!(),
        },
        steps: 4,
        eval_every: 0,
        log_every: 1,
        artifacts_dir: artifacts(),
        train_examples: 64,
        val_examples: 16,
        lr: 1e-3,
        ..TrainConfig::default()
    }
}

/// Reference quantized forward that stores EVERY activation (test-only).
fn forward_quant_storing_all(
    stack: &Stack,
    params: &ParamStore,
    x0: Tensor,
    plan: &GammaPlan,
) -> Vec<Tensor> {
    let mut x0q = x0;
    quant::quantize_activation(&mut x0q, stack.fixed);
    stack.forward_float_like_quant(params, x0q, plan)
}

trait QuantRecorder {
    fn forward_float_like_quant(
        &self,
        params: &ParamStore,
        x0q: Tensor,
        plan: &GammaPlan,
    ) -> Vec<Tensor>;
}

impl QuantRecorder for Stack<'_> {
    /// Independent re-implementation of eqs. 18-21 used only as the test
    /// oracle: tracks all activations with the same fixed-point combine.
    fn forward_float_like_quant(
        &self,
        params: &ParamStore,
        x0q: Tensor,
        plan: &GammaPlan,
    ) -> Vec<Tensor> {
        let f = self.fixed;
        let h0 = self.debug_call_fwd(params, 0, &x0q, None).unwrap();
        let x1 = quant::first_step_quant(&x0q, &h0, f).unwrap();
        let mut xs = vec![x0q, x1];
        for k in 1..self.n_blocks {
            let h = self.debug_call_fwd(params, k, &xs[k], None).unwrap();
            let signs = plan.signs(k).unwrap();
            let (x_next, _bits) =
                quant::bdia_forward_quant(&xs[k - 1], &xs[k], &h, &signs, f).unwrap();
            xs.push(x_next);
        }
        xs
    }
}

#[test]
fn reversible_reconstruction_is_bitwise_exact() {
    let rt = load("smoke_gpt");
    let params = ParamStore::init(&rt.manifest, 5);
    let stack = Stack::new(&rt, StackKind::Main).unwrap();
    let dims = &rt.manifest.dims;
    let mut rng = Rng::new(1);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);

    // oracle record of all activations
    let xs_ref = forward_quant_storing_all(&stack, &params, x0.clone(), &plan);

    // production path: boundaries + side info only, then reconstruct
    let state = stack.forward_quant(&params, x0, None, &plan).unwrap();
    let xs_rec = stack.reconstruct_all(&params, &state, None, &plan).unwrap();

    assert_eq!(xs_ref.len(), xs_rec.len());
    for (k, (a, b)) in xs_ref.iter().zip(&xs_rec).enumerate() {
        assert_eq!(a.data(), b.data(), "activation x_{k} reconstruction drifted");
    }
}

#[test]
fn online_backward_gradients_match_store_all_bitwise() {
    let rt = load("smoke_gpt");
    let params = ParamStore::init(&rt.manifest, 6);
    let stack = Stack::new(&rt, StackKind::Main).unwrap();
    let dims = &rt.manifest.dims;
    let mut rng = Rng::new(2);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);
    let gx = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

    // path A: reversible (reconstructing) backward
    let state = stack.forward_quant(&params, x0.clone(), None, &plan).unwrap();
    let ga = stack
        .backward(&params, state, None, &plan, gx.clone())
        .unwrap();

    // path B: store-all backward over the same quantized activations
    let mut x0q = x0;
    quant::quantize_activation(&mut x0q, stack.fixed);
    let xs = stack.forward_float_like_quant(&params, x0q, &plan);
    let gb = stack
        .backward(&params, StackState::Full { xs }, None, &plan, gx)
        .unwrap();

    assert_eq!(ga.dx0.data(), gb.dx0.data(), "dx0 must match bitwise");
    for (k, (da, db)) in ga.dparams.iter().zip(&gb.dparams).enumerate() {
        for (i, (a, b)) in da.iter().zip(db).enumerate() {
            assert_eq!(a.data(), b.data(), "dparams[{k}][{i}] drifted");
        }
    }
}

#[test]
fn float_inversion_drift_grows_with_depth() {
    // the Fig.-2 phenomenon: eq.-16 float inversion error amplifies ~2x per
    // block, while the quantized path is exactly zero (previous tests).
    let rt = load("smoke_gpt");
    let params = ParamStore::init(&rt.manifest, 7);
    let stack = Stack::new(&rt, StackKind::Main).unwrap();
    let dims = &rt.manifest.dims;
    let mut rng = Rng::new(3);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);

    let StackState::Full { xs } = stack
        .forward_float(&params, x0, None, &plan)
        .unwrap()
    else {
        panic!()
    };
    // invert top-down in float (eq. 16) re-using the stored x_k (so drift
    // comes purely from the inversion arithmetic, like Fig. 2)
    let k_total = stack.n_blocks;
    let mut x_next = xs[k_total].clone();
    let mut x_cur = xs[k_total - 1].clone();
    let mut drifts = Vec::new();
    for k in (1..k_total).rev() {
        let h = stack.debug_call_fwd(&params, k, &x_cur, None).unwrap();
        let rec = quant::bdia_invert_float(&x_next, &x_cur, &h, &plan.gammas[k]).unwrap();
        let drift = rec.max_abs_diff(&xs[k - 1]).unwrap();
        drifts.push(drift);
        x_next = x_cur;
        x_cur = rec; // propagate the drifted value, like real online backprop
    }
    // the 1/gamma = 2 factor amplifies f32 rounding multiplicatively, so
    // the deepest reconstruction must be strictly worse than the first and
    // clearly above single-op rounding noise (~1e-7 at these magnitudes)
    let first = drifts.first().copied().unwrap();
    let last = drifts.last().copied().unwrap();
    assert!(last > first, "drift must accumulate: {drifts:?}");
    assert!(last > 2e-7, "deep drift should be visible: {drifts:?}");
}

#[test]
fn trainers_descend_all_families() {
    for bundle in ["smoke_vit", "smoke_gpt", "smoke_encdec"] {
        for mode in [TrainMode::BdiaReversible, TrainMode::Vanilla] {
            let cfg = cfg_for(bundle, mode);
            let mut tr = Trainer::new(cfg.clone()).unwrap();
            let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family).unwrap();
            let mut losses = Vec::new();
            for step in 0..cfg.steps {
                let b = ds.train_batch(step);
                let stats = tr.train_step(&b).unwrap();
                assert!(stats.loss.is_finite(), "{bundle}/{mode:?} loss blew up");
                losses.push(stats.loss);
            }
            // same batch pool: after a few steps the loss on batch 0 drops
            let b0 = ds.train_batch(0);
            let fs = tr.forward(&b0).unwrap();
            assert!(
                fs.loss < losses[0] + 0.05,
                "{bundle}/{mode:?}: no descent ({} -> {})",
                losses[0],
                fs.loss
            );
        }
    }
}

#[test]
fn reversible_stores_less_than_vanilla_live() {
    let run = |mode| {
        let cfg = cfg_for("smoke_gpt", mode);
        let mut tr = Trainer::new(cfg.clone()).unwrap();
        let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family).unwrap();
        let b = ds.train_batch(0);
        tr.train_step(&b).unwrap().stored_activation_bytes
    };
    let rev = run(TrainMode::BdiaReversible);
    let van = run(TrainMode::Vanilla);
    // smoke_gpt: K=4 blocks -> store-all keeps 5 tensors, reversible keeps 2
    // (+ side bits). Live numbers, not the analytic model.
    assert!(rev < van, "reversible {rev} vs vanilla {van}");
    let dims = load("smoke_gpt").manifest.dims;
    let btd = dims.batch * dims.seq * dims.d_model * 4;
    assert_eq!(van, (dims.n_blocks + 1) * btd);
    let side = (dims.n_blocks - 1) * (btd / 4).div_ceil(64) * 8;
    assert_eq!(rev, 2 * btd + side);
}

#[test]
fn revvit_trains_and_inversion_drift_is_small_but_nonzero() {
    let cfg = cfg_for("smoke_vit", TrainMode::RevVit);
    let mut tr = RevVitTrainer::new(cfg.clone()).unwrap();
    let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), bdia::model::Family::Vit)
        .unwrap();
    let mut first = None;
    for step in 0..cfg.steps {
        let b = ds.train_batch(step);
        let s = tr.train_step(&b).unwrap();
        assert!(s.loss.is_finite());
        first.get_or_insert(s.loss);
    }
    // float inversion: drift exists in principle but stays tiny on 3 blocks
    assert!(tr.inversion_drift.is_finite());
    assert!(tr.inversion_drift < 1e-3, "drift {}", tr.inversion_drift);
    let (vl, va) = tr.evaluate(ds.as_ref(), 2).unwrap();
    assert!(vl.is_finite() && (0.0..=1.0).contains(&va));
}

#[test]
fn bdia_reversible_rejects_non_half_gamma() {
    let mut cfg = cfg_for("smoke_gpt", TrainMode::BdiaReversible);
    cfg.gamma_mag = 0.25;
    assert!(Trainer::new(cfg).is_err(), "|gamma| != 0.5 must be rejected");
}

#[test]
fn bdia_float_supports_ablation_gammas() {
    for mag in [0.0f32, 0.25, 0.5, 0.6] {
        let mut cfg = cfg_for("smoke_gpt", TrainMode::BdiaFloat);
        cfg.gamma_mag = mag;
        cfg.steps = 2;
        let mut tr = Trainer::new(cfg.clone()).unwrap();
        let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family).unwrap();
        let b = ds.train_batch(0);
        let s = tr.train_step(&b).unwrap();
        assert!(s.loss.is_finite(), "gamma_mag {mag}");
    }
}

#[test]
fn eval_gamma_sweep_runs() {
    let cfg = cfg_for("smoke_vit", TrainMode::Vanilla);
    let tr = Trainer::new(cfg.clone()).unwrap();
    let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family).unwrap();
    for gamma in [-0.5f32, 0.0, 0.5] {
        let (l, a) = tr.evaluate(ds.as_ref(), 1, gamma).unwrap();
        assert!(l.is_finite() && (0.0..=1.0).contains(&a), "gamma {gamma}");
    }
}

#[test]
fn corrupted_side_info_detected_or_changes_grads() {
    // failure injection: the quant layer already unit-tests bit flips; at
    // system level we check a *missing* side-info entry fails loudly.
    let rt = load("smoke_gpt");
    let params = ParamStore::init(&rt.manifest, 8);
    let stack = Stack::new(&rt, StackKind::Main).unwrap();
    let dims = &rt.manifest.dims;
    let mut rng = Rng::new(4);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);
    let state = stack.forward_quant(&params, x0, None, &plan).unwrap();
    let StackState::Reversible { x_last, x_prev, mut side } = state else {
        panic!()
    };
    side.take(stack.n_blocks - 1); // lose one block's side info
    let res = stack.backward(
        &params,
        StackState::Reversible { x_last, x_prev, side },
        None,
        &plan,
        Tensor::zeros(&[dims.batch, dims.seq, dims.d_model]),
    );
    assert!(res.is_err(), "missing side info must be a hard error");
}
