//! The `bdia::api` facade surface: builder defaults match
//! `TrainConfig::default()`, the Session path is bit-identical to the
//! pre-facade `Trainer` path (train → save → resume), `infer_batch` is
//! bit-identical to a raw `model_infer_ex` executable call, `ApiError`
//! variants are structured and matchable, and the `EventSink` observer
//! delivers ordered step events and gamma-tagged eval events.

use bdia::api::{
    ApiError, Collector, EvalOpts, Event, ModelId, ServeOpts, Session,
    TrainOpts,
};
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use bdia::model::ParamStore;
use bdia::serve::{client, wire};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg_for(bundle: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        model: bundle.into(),
        mode: TrainMode::BdiaReversible,
        dataset: match bundle {
            "smoke_vit" => "synth_cifar10".into(),
            "smoke_gpt" => "tiny_corpus".into(),
            "smoke_encdec" => "synth_translation".into(),
            _ => unreachable!(),
        },
        steps,
        eval_every: 0,
        log_every: 1,
        artifacts_dir: artifacts(),
        train_examples: 64,
        val_examples: 16,
        lr: 1e-3,
        ..TrainConfig::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("bdia_api_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Flatten every parameter to its raw bit pattern (exact comparison).
fn store_bits(ps: &ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// builder defaults
// ---------------------------------------------------------------------------

#[test]
fn builder_defaults_match_train_config_default() {
    let session = Session::builder().build().unwrap();
    assert_eq!(session.config(), &TrainConfig::default());
    assert_eq!(session.step(), 0);
    assert!(session.resumed_from().is_none());
    assert!(session.provenance().contains("untrained"));
}

#[test]
fn builder_setters_land_in_config() {
    let session = Session::builder()
        .model(ModelId::SmokeGpt)
        .dataset("tiny_corpus")
        .steps(7)
        .seed(3)
        .threads(2)
        .eval_every(5)
        .eval_batches(2)
        .override_kv("lr=0.01")
        .build()
        .unwrap();
    let cfg = session.config();
    assert_eq!(cfg.model, "smoke_gpt");
    assert_eq!(cfg.steps, 7);
    assert_eq!(cfg.seed, 3);
    assert_eq!(cfg.threads, 2);
    assert_eq!(cfg.lr, 0.01);
    assert_eq!(session.model(), ModelId::SmokeGpt.name());
}

// ---------------------------------------------------------------------------
// bit-identity with the pre-facade paths
// ---------------------------------------------------------------------------

#[test]
fn session_train_save_resume_bit_identical_to_trainer_path() {
    let cfg = cfg_for("smoke_vit", 4);

    // pre-facade reference: construct the Trainer directly
    let mut tr = Trainer::new(cfg.clone()).unwrap();
    let ds = dataset_for(&tr.rt, &cfg).unwrap();
    tr.run(ds.as_ref(), "legacy").unwrap();

    // facade path on the identical config
    let mut session = Session::builder().config(cfg.clone()).build().unwrap();
    let report = session.train(&TrainOpts::default()).unwrap();
    assert_eq!(report.steps_completed, 4);
    assert_eq!(report.log.records.len(), 4); // log_every = 1
    assert_eq!(store_bits(session.params()), store_bits(&tr.params));

    // save -> resume -> continue must equal an uninterrupted longer run
    let dir = tmp_dir("resume");
    let ckpt = dir.join("s4.ckpt");
    session.save(&ckpt).unwrap();

    let longer = TrainConfig { steps: 8, ..cfg.clone() };
    let mut resumed = Session::builder()
        .config(longer.clone())
        .checkpoint(&ckpt)
        .build()
        .unwrap();
    assert_eq!(resumed.step(), 4);
    assert!(resumed.provenance().contains("s4.ckpt"));
    resumed.train(&TrainOpts::default()).unwrap();

    let mut full = Session::builder().config(longer).build().unwrap();
    full.train(&TrainOpts::default()).unwrap();

    assert_eq!(store_bits(resumed.params()), store_bits(full.params()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn infer_batch_bit_identical_to_raw_model_infer_ex() {
    let mut session =
        Session::builder().config(cfg_for("smoke_gpt", 2)).build().unwrap();
    session.train(&TrainOpts::default()).unwrap(); // score trained weights
    let ds = session.dataset().unwrap();
    let examples = wire::examples_from_batch(&ds.val_batch(0));
    let gamma = 0.25f32;

    let got = session.infer_batch(&examples, gamma).unwrap();

    // reference: the raw executable, bypassing the facade entirely
    let rt = session.runtime();
    let e = rt.exec("model_infer_ex").unwrap();
    let refs = session.params().refs_for(&e.spec, 0).unwrap();
    let packed =
        wire::assemble(rt.manifest.family, &rt.manifest.dims, &examples).unwrap();
    let outs = e.call(&refs, &packed.args(gamma)).unwrap();
    let (loss, correct) = (outs[0].data(), outs[1].data());

    assert_eq!(got.len(), examples.len());
    for (i, (l, c)) in got.iter().enumerate() {
        assert_eq!(l.to_bits(), loss[i].to_bits(), "loss slot {i}");
        assert_eq!(c.to_bits(), correct[i].to_bits(), "correct slot {i}");
    }

    // single-example entry point hits the same path
    let (l0, c0) = session.infer(&examples[0], gamma).unwrap();
    assert_eq!(l0.to_bits(), got[0].0.to_bits());
    assert_eq!(c0.to_bits(), got[0].1.to_bits());
}

// ---------------------------------------------------------------------------
// error taxonomy
// ---------------------------------------------------------------------------

#[test]
fn unknown_model_error_is_structured_and_lists_names() {
    let err = Session::builder()
        .model_name("vit_s1O") // typo: O for 0
        .artifacts_dir("/nonexistent/artifacts")
        .build()
        .unwrap_err();
    let ApiError::UnknownModel { name, known } = &err else {
        panic!("expected UnknownModel, got {err:?}")
    };
    assert_eq!(name, "vit_s1O");
    assert_eq!(known, &ModelId::known_names());
    let msg = err.to_string();
    assert!(msg.contains("did you mean 'vit_s10'"), "{msg}");
    assert!(msg.contains("smoke_encdec"), "{msg}");
}

#[test]
fn config_errors_for_bad_override_and_bad_mode_combo() {
    let err = Session::builder().override_kv("nonsense=1").build().unwrap_err();
    assert!(matches!(err, ApiError::Config(_)), "{err:?}");

    // |gamma| != 0.5 breaks exact bit-level reversibility in bdia mode
    let err = Session::builder()
        .config(cfg_for("smoke_vit", 1))
        .gamma_mag(0.25)
        .build()
        .unwrap_err();
    assert!(matches!(err, ApiError::Config(_)), "{err:?}");
    assert!(err.to_string().contains("0.5"), "{err}");
}

#[test]
fn checkpoint_error_carries_the_path() {
    let err = Session::builder()
        .config(cfg_for("smoke_vit", 1))
        .checkpoint("/nonexistent/dir/x.ckpt")
        .build()
        .unwrap_err();
    let ApiError::Checkpoint(ck) = &err else {
        panic!("expected Checkpoint, got {err:?}")
    };
    assert_eq!(ck.path, PathBuf::from("/nonexistent/dir/x.ckpt"));
    // the std::error::Error chain exposes the checkpoint error as source
    let source = std::error::Error::source(&err).expect("source");
    assert!(source.to_string().contains("x.ckpt"));
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_without_feature_is_a_backend_error() {
    let err = Session::builder()
        .config(cfg_for("smoke_vit", 1))
        .backend(bdia::runtime::BackendKind::Pjrt)
        .build()
        .unwrap_err();
    assert!(matches!(err, ApiError::Backend(_)), "{err:?}");
    assert!(err.to_string().contains("feature"), "{err}");
}

#[test]
fn revvit_engine_rejects_persistence_with_config_error() {
    let mut cfg = cfg_for("smoke_vit", 1);
    cfg.mode = TrainMode::RevVit;
    let mut session = Session::builder().config(cfg).build().unwrap();
    let err = session.resume(Path::new("/nonexistent/x.ckpt")).unwrap_err();
    assert!(matches!(err, ApiError::Config(_)), "{err:?}");
    let err = session.save(Path::new("/tmp/never.ckpt")).unwrap_err();
    assert!(matches!(err, ApiError::Config(_)), "{err:?}");
}

// ---------------------------------------------------------------------------
// event sink
// ---------------------------------------------------------------------------

#[test]
fn event_sink_step_ordering_and_eval_gamma() {
    let collector = Arc::new(Collector::new());
    let cfg = TrainConfig {
        eval_every: 2,
        eval_batches: 1,
        ..cfg_for("smoke_vit", 5)
    };
    let mut session = Session::builder()
        .config(cfg)
        .event_sink(collector.clone())
        .build()
        .unwrap();
    session.train(&TrainOpts::default()).unwrap();
    session
        .evaluate(&EvalOpts { gamma: 0.25, batches: Some(1) })
        .unwrap();

    let events = collector.events();
    let steps: Vec<usize> = events
        .iter()
        .filter_map(|e| match e {
            Event::Step(s) => Some(s.step),
            _ => None,
        })
        .collect();
    // one event per optimization step, strictly increasing from 0
    assert_eq!(steps, (0..5).collect::<Vec<_>>());
    assert!(steps.windows(2).all(|w| w[0] < w[1]));

    let evals: Vec<(usize, f32)> = events
        .iter()
        .filter_map(|e| match e {
            Event::Eval(e) => Some((e.step, e.gamma)),
            _ => None,
        })
        .collect();
    // loop evals at steps 2, 4, 5 carry the loop's gamma = 0.0; the manual
    // evaluate carries the gamma it was asked for
    assert_eq!(evals.len(), 4, "{evals:?}");
    assert!(evals[..3].iter().all(|&(_, g)| g.to_bits() == 0.0f32.to_bits()));
    assert_eq!(evals[3].1.to_bits(), 0.25f32.to_bits());

    // every timed event carries a monotonic elapsed_us stamp: the stream
    // is orderable without consulting any wall clock
    let elapsed: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            Event::Step(s) => Some(s.elapsed_us),
            Event::Eval(ev) => Some(ev.elapsed_us),
            Event::Request(r) => Some(r.elapsed_us),
            Event::Token(t) => Some(t.elapsed_us),
            Event::Checkpoint(_) => None,
        })
        .collect();
    assert!(elapsed.len() >= steps.len() + evals.len());
    assert!(
        elapsed.windows(2).all(|w| w[0] <= w[1]),
        "elapsed_us must be non-decreasing: {elapsed:?}"
    );

    // saving emits a checkpoint event carrying the path
    let dir = tmp_dir("events");
    let ckpt = dir.join("ev.ckpt");
    session.save(&ckpt).unwrap();
    let last = collector.events().pop().unwrap();
    let Event::Checkpoint(c) = last else { panic!("want checkpoint event") };
    assert_eq!(c.step, 5);
    assert_eq!(c.path, ckpt);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// serving through the facade
// ---------------------------------------------------------------------------

#[test]
fn session_serve_uses_in_memory_params_and_emits_request_events() {
    let collector = Arc::new(Collector::new());
    let mut session = Session::builder()
        .config(cfg_for("smoke_vit", 2))
        .event_sink(collector.clone())
        .build()
        .unwrap();
    // train in-session; the server must serve these weights with no
    // checkpoint file involved
    session.train(&TrainOpts::default()).unwrap();

    let handle = session
        .serve(&ServeOpts {
            port: 0,
            workers: 1,
            batch_window: Duration::from_micros(100),
            ..ServeOpts::default()
        })
        .unwrap();
    let ds = session.dataset().unwrap();
    let ex = &wire::examples_from_batch(&ds.val_batch(0))[0];
    let served = client::infer(handle.addr(), &wire::encode(ex, 0.0)).unwrap();
    let local = session.infer(ex, 0.0).unwrap();
    handle.shutdown().unwrap();

    assert_eq!(served.0.to_bits(), local.0.to_bits());
    assert_eq!(served.1.to_bits(), local.1.to_bits());
    assert!(
        collector
            .events()
            .iter()
            .any(|e| matches!(e, Event::Request(r) if r.ok)),
        "serving must emit request events to the session sink"
    );
}
