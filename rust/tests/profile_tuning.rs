//! Tuning-profile acceptance suite: ANY legal kernel profile is bit-exact
//! by construction.
//!
//! The profile knobs (`kc`, `grain_flop`, `unroll`, `nt_cache`) can only
//! regroup loops, move task-split boundaries, chunk independent output
//! elements, or reuse a bitwise-identical cached transpose — never change
//! a per-element reduction order.  This suite drives that claim over
//! pseudo-random legal profiles × thread counts for every kernel entry
//! point, and pins the persistence contract: `bdia tune` output survives
//! save → load byte-identically, while corrupt or wrong-version files are
//! rejected with clear errors and fall back to the default profile.

use bdia::api::{Session, TuneOpts};
use bdia::kernels::profile::{self, reset_active, set_active, OpKey};
use bdia::kernels::{
    attn_bwd, attn_fwd, linear, matmul, matmul_nt, matmul_nt_w, matmul_tn,
    pool, workspace, AttnW, KernelProfile, OpKind, OpParams,
};
use std::sync::{Mutex, MutexGuard};

/// Every test here mutates the process-global active profile; libtest runs
/// tests concurrently, so they serialize on one lock.
static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Deterministic pseudo-random data (xorshift32), same bits every call.
fn synth(n: usize, seed: u32) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9e37_79b9) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 17;
            s ^= s << 5;
            (s as f64 / u32::MAX as f64) as f32 - 0.5
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One pass over every tunable kernel entry point, output as bits.  The
/// shapes straddle k-panel and grain boundaries; the inputs carry
/// 0·inf and -0.0 so IEEE faithfulness is stressed too.
fn run_all(threads: usize) -> Vec<u32> {
    pool::set_threads(threads);
    // the nt weight below is reallocated per call: invalidate any keyed
    // transpose from a previous run, as every in-tree replacement path does
    workspace::bump_weight_generation();
    let (m, k, n) = (23usize, 65usize, 33usize);
    let mut a = synth(m * k, 1);
    let mut b = synth(k * n, 2);
    a[0] = f32::INFINITY;
    a[1] = -0.0;
    b[0] = 0.0;
    let mut out = Vec::new();
    out.extend(bits(&matmul(&a, &b, m, k, n)));
    let bias = synth(n, 3);
    out.extend(bits(&linear(&a, &b, &bias, m, k, n)));
    // matmul_tn: a (m,k), b2 (m,n) -> (k,n), reduction over m
    let b2 = synth(m * n, 4);
    out.extend(bits(&matmul_tn(&a, &b2, m, k, n)));
    // matmul_nt: a2 (m,n), w (k,n) -> (m,k), reduction over n
    let a2 = synth(m * n, 5);
    let w = synth(k * n, 6);
    out.extend(bits(&matmul_nt(&a2, &w, m, n, k)));
    out.extend(bits(&matmul_nt_w(&a2, &w, m, n, k)));
    // attention fwd + bwd, parallel across (batch, head) pairs
    let (ab, t, d, heads) = (3usize, 12usize, 16usize, 4usize);
    let x = synth(ab * t * d, 7);
    let wq = synth(d * d, 8);
    let wk = synth(d * d, 9);
    let wv = synth(d * d, 10);
    let wo = synth(d * d, 11);
    let bq = synth(d, 12);
    let bk = synth(d, 13);
    let bv = synth(d, 14);
    let bo = synth(d, 15);
    let aw = AttnW {
        wq: &wq,
        bq: &bq,
        wk: &wk,
        bk: &bk,
        wv: &wv,
        bv: &bv,
        wo: &wo,
        bo: &bo,
    };
    let (y, cache) = attn_fwd(&aw, &x, &x, ab, t, t, d, heads, true);
    let dout = synth(ab * t * d, 16);
    let (dx, dkv, grads) = attn_bwd(&aw, &x, &x, &cache, &dout, ab, t, t, d, heads);
    cache.recycle();
    out.extend(bits(&y));
    out.extend(bits(&dx));
    out.extend(bits(&dkv));
    out.extend(bits(&grads.wq));
    out.extend(bits(&grads.bo));
    out
}

/// A pseudo-random legal profile: every knob drawn from its legal range.
fn rnd_profile(seed: u32) -> KernelProfile {
    let mut s = seed.wrapping_mul(0x6c07_8965).wrapping_add(1) | 1;
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 17;
        s ^= s << 5;
        s as usize
    };
    const KCS: [usize; 10] = [1, 3, 16, 32, 48, 64, 100, 128, 256, 511];
    const GRAINS: [usize; 7] = [1, 64, 1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 20];
    const UNROLLS: [usize; 5] = [1, 2, 4, 8, 16];
    KernelProfile {
        id: format!("rnd-{seed}"),
        default_params: OpParams {
            kc: KCS[next() % KCS.len()],
            grain_flop: GRAINS[next() % GRAINS.len()],
            unroll: UNROLLS[next() % UNROLLS.len()],
            nt_cache: next() % 2 == 0,
        },
        ..KernelProfile::default()
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bdia_profile_tuning_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn randomized_legal_profiles_are_bit_identical_across_ops_and_threads() {
    let _g = guard();
    reset_active();
    let base = run_all(1);
    assert!(!base.is_empty());
    for seed in 0..20u32 {
        let p = rnd_profile(seed);
        p.validate().expect("generated profile must be legal");
        for threads in [1usize, 2, 4, 7] {
            set_active(p.clone(), None);
            let got = run_all(threads);
            reset_active();
            assert!(
                base == got,
                "profile {} (kc={} grain_flop={} unroll={} nt_cache={}) \
                 drifted at {threads} threads",
                p.id,
                p.default_params.kc,
                p.default_params.grain_flop,
                p.default_params.unroll,
                p.default_params.nt_cache
            );
        }
    }
    pool::set_threads(0);
}

#[test]
fn per_shape_entries_shadow_the_fallback_and_stay_bit_identical() {
    let _g = guard();
    reset_active();
    pool::set_threads(2);
    let (m, k, n) = (23usize, 65usize, 33usize);
    let a = synth(m * k, 21);
    let b = synth(k * n, 22);
    let want = matmul(&a, &b, m, k, n);
    // an entry keyed to exactly this shape at exactly this thread count
    let mut p = KernelProfile {
        id: "entries-test".into(),
        ..KernelProfile::default()
    };
    p.entries.insert(
        OpKey { op: OpKind::Matmul, m, k, n, threads: 2 },
        OpParams { kc: 5, grain_flop: 256, unroll: 16, nt_cache: false },
    );
    p.validate().expect("legal profile");
    set_active(p, None);
    let got = matmul(&a, &b, m, k, n);
    reset_active();
    assert!(
        bits(&want) == bits(&got),
        "a per-shape entry changed matmul bits"
    );
    pool::set_threads(0);
}

#[test]
fn session_tune_persists_and_reloads_byte_identically() {
    let _g = guard();
    reset_active();
    let dir = tmp_dir("tune");
    let path = dir.join("tuned.json");
    let mut session = Session::builder()
        .model_name("smoke_vit")
        .dataset_auto()
        .threads(2)
        .build()
        .expect("session");
    let report =
        session.tune(&TuneOpts { quick: true, out: Some(path.clone()) }).expect("tune");
    assert!(report.shapes_tuned > 0, "tuning found no shapes");
    assert_eq!(report.threads, 2);
    assert_eq!(report.profile.entries.len(), report.shapes_tuned);
    // the search must restore the ambient (default) profile afterwards
    assert_eq!(profile::active_id(), "default");
    // persisted as versioned JSON, loads back equal, re-saves identically
    let text = std::fs::read_to_string(&path).expect("profile file");
    assert!(text.contains("\"bdia_profile\": 1"), "unversioned: {text}");
    let back = KernelProfile::load(&path).expect("load");
    assert_eq!(back, report.profile);
    let path2 = dir.join("tuned2.json");
    back.save(&path2).expect("re-save");
    assert_eq!(
        std::fs::read(&path).unwrap(),
        std::fs::read(&path2).unwrap(),
        "save -> load -> save is not byte-identical"
    );
    // a fresh session picks the persisted profile up via the builder hook
    let s2 = Session::builder()
        .model_name("smoke_vit")
        .dataset_auto()
        .tune_profile(&path)
        .build()
        .expect("session under tuned profile");
    assert_eq!(profile::active_id(), back.id);
    assert_eq!(profile::active_source().as_deref(), Some(path.as_path()));
    drop(s2);
    reset_active();
    pool::set_threads(0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_or_wrong_version_profiles_are_rejected_and_fall_back() {
    let _g = guard();
    reset_active();
    let dir = tmp_dir("reject");
    // corrupt JSON: load fails with an error naming the file
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{ this is not json").unwrap();
    let err = format!("{:#}", KernelProfile::load(&bad).unwrap_err());
    assert!(err.contains("bad.json"), "error must name the file: {err}");
    assert!(err.contains("not valid JSON"), "unhelpful error: {err}");
    // wrong version: rejected with both versions in the message
    let wrong = dir.join("wrong.json");
    let doc = KernelProfile::default()
        .to_json_string()
        .replacen("\"bdia_profile\": 1", "\"bdia_profile\": 2", 1);
    std::fs::write(&wrong, doc).unwrap();
    let err = format!("{:#}", KernelProfile::load(&wrong).unwrap_err());
    assert!(err.contains("version 2"), "unhelpful error: {err}");
    // the session builder warns and falls back to the default profile
    // instead of refusing to start
    let s = Session::builder()
        .model_name("smoke_vit")
        .dataset_auto()
        .tune_profile(&bad)
        .build()
        .expect("build must fall back, not fail");
    assert_eq!(profile::active_id(), "default");
    drop(s);
    reset_active();
    pool::set_threads(0);
    std::fs::remove_dir_all(&dir).ok();
}
