//! Generation integration: incremental KV-cache decoding must match a full
//! re-forward of the whole prefix bit-exactly at every thread count and
//! under a non-default kernel profile; sampled decoding must replay
//! bit-exactly from a saved seed; the streaming `/generate` endpoint must
//! return the same tokens as `Session::generate` over real sockets (even
//! under concurrent load, where sessions batch into shared decode ticks);
//! and `init_from` fine-tuning must be mechanically identical to resuming
//! from the same checkpoint — with `freeze_embed` pinning the embedding
//! bitwise while the rest of the model trains.

use bdia::api::{NullSink, Session};
use bdia::config::json::Json;
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use bdia::fleet::{FleetConfig, Router};
use bdia::generate::{run_session, GenOpts, GenSession, GenStop};
use bdia::kernels::pool;
use bdia::kernels::profile::{reset_active, set_active};
use bdia::kernels::{KernelProfile, OpParams};
use bdia::model::ParamStore;
use bdia::runtime::{ArgValue, Runtime};
use bdia::serve::{client, http, ServeConfig, Server};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Seed-0 runtime + params — the same pair a ckpt-less server initializes.
fn reference() -> (Runtime, ParamStore) {
    let rt = Runtime::load(&artifacts(), "smoke_gpt").unwrap();
    let params = ParamStore::init(&rt.manifest, 0);
    (rt, params)
}

fn cfg_gpt() -> TrainConfig {
    TrainConfig {
        model: "smoke_gpt".into(),
        mode: TrainMode::BdiaReversible,
        dataset: "tiny_corpus".into(),
        steps: 4,
        eval_every: 0,
        log_every: 1,
        artifacts_dir: artifacts(),
        train_examples: 64,
        val_examples: 16,
        lr: 1e-3,
        ..TrainConfig::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("bdia_gen_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn store_bits(ps: &ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

fn group_bits(ps: &ParamStore, group: &str) -> Vec<u32> {
    let mut out = Vec::new();
    for inst in ps.groups.get(group).expect("group exists") {
        for t in inst {
            out.extend(t.data().iter().map(|v| v.to_bits()));
        }
    }
    out
}

/// Greedy continuation computed the expensive way: re-forward the whole
/// prefix through `model_logits` for every position and argmax the last
/// valid row (first maximum — the same tie-break as the decode sampler).
fn greedy_full_reforward(
    rt: &Runtime,
    params: &ParamStore,
    prompt: &[i32],
) -> Vec<i32> {
    let dims = rt.manifest.dims.clone();
    let e = rt.exec("model_logits").unwrap();
    let refs = params.refs_for(&e.spec, 0).unwrap();
    let mut toks = prompt.to_vec();
    let mut out = Vec::new();
    while toks.len() < dims.seq {
        let len = toks.len();
        let mut padded = vec![0i32; dims.batch * dims.seq];
        padded[..len].copy_from_slice(&toks); // lane 0 carries the prefix
        let tt =
            bdia::tensor::IntTensor::from_vec(&[dims.batch, dims.seq], padded)
                .unwrap();
        let logits = e
            .call(
                &refs,
                &[
                    ArgValue::I32(&tt),
                    ArgValue::Scalar(len as f32),
                    ArgValue::Scalar(0.0),
                ],
            )
            .unwrap()
            .remove(0);
        let row = &logits.data()[(len - 1) * dims.vocab..len * dims.vocab];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        out.push(best as i32);
        toks.push(best as i32);
    }
    out
}

/// A deliberately non-default kernel profile (every knob off its default);
/// legal profiles may only change wall time, never bytes.
fn nondefault_profile() -> KernelProfile {
    KernelProfile {
        id: "generate-it-tuned".into(),
        default_params: OpParams {
            kc: 48,
            grain_flop: 1 << 12,
            unroll: 8,
            nt_cache: true,
        },
        ..KernelProfile::default()
    }
}

#[test]
fn incremental_decode_matches_full_reforward_across_threads_and_profiles() {
    let (rt, params) = reference();
    let dims = rt.manifest.dims.clone();
    let prompt = [3i32, 1, 4];
    // reference continuation: full prefix re-forward at every step
    let want = greedy_full_reforward(&rt, &params, &prompt);
    assert_eq!(want.len(), dims.seq - prompt.len());

    for threads in [1usize, 2, 4, 7] {
        for tuned in [false, true] {
            pool::set_threads(threads);
            if tuned {
                set_active(nondefault_profile(), None);
            }
            let mut s = GenSession::new(
                &rt,
                &prompt,
                GenOpts { max_tokens: 32, ..GenOpts::default() },
            )
            .unwrap();
            let rep = run_session(&rt, &params, &mut s, |_, _, _| {}).unwrap();
            if tuned {
                reset_active();
            }
            assert_eq!(
                rep.tokens, want,
                "incremental decode diverged from full re-forward at \
                 {threads} threads (tuned profile: {tuned})"
            );
            assert_eq!(rep.stop, GenStop::ContextFull);
            assert_eq!(rep.prompt_len, prompt.len());
            assert_eq!(rep.token_ms.len(), rep.tokens.len());
        }
    }
    pool::set_threads(0);
}

#[test]
fn sampled_generation_replays_bit_exactly_from_a_saved_seed() {
    let (rt, params) = reference();
    let opts = GenOpts {
        max_tokens: 5,
        temperature: 0.9,
        top_k: 4,
        seed: 1234,
        ..GenOpts::default()
    };
    let run = |threads: usize| {
        pool::set_threads(threads);
        let mut s = GenSession::new(&rt, &[2, 7], opts.clone()).unwrap();
        run_session(&rt, &params, &mut s, |_, _, _| {}).unwrap()
    };
    let a = run(1);
    let b = run(3); // replay at a different thread count: still exact
    assert_eq!(a.tokens, b.tokens, "saved seed did not replay bit-exactly");
    assert_eq!(a.stop, GenStop::MaxTokens);
    assert_eq!(a.tokens.len(), 5);

    // eos stops generation the moment the token appears (still emitted)
    let greedy = greedy_full_reforward(&rt, &params, &[2, 7]);
    let eos = greedy[1];
    let cut = greedy.iter().position(|&t| t == eos).unwrap();
    let mut s = GenSession::new(
        &rt,
        &[2, 7],
        GenOpts { max_tokens: 32, eos: Some(eos), ..GenOpts::default() },
    )
    .unwrap();
    let rep = run_session(&rt, &params, &mut s, |_, _, _| {}).unwrap();
    assert_eq!(rep.stop, GenStop::Eos);
    assert_eq!(rep.tokens, greedy[..=cut].to_vec());
    pool::set_threads(0);
}

/// One streaming request over a raw socket; returns (streamed token lines,
/// terminal summary JSON).
fn stream_generate(
    addr: std::net::SocketAddr,
    body: &str,
) -> (Vec<(usize, i32)>, Json) {
    let stream = TcpStream::connect(addr).unwrap();
    http::write_request(&stream, "POST", "/generate", body.as_bytes()).unwrap();
    let (status, chunks) = http::read_chunked_response(&stream).unwrap();
    assert_eq!(status, 200);
    assert!(!chunks.is_empty(), "stream ended without a terminal chunk");
    let mut tokens = Vec::new();
    for c in &chunks[..chunks.len() - 1] {
        let j = Json::parse(&String::from_utf8(c.clone()).unwrap()).unwrap();
        tokens.push((
            j.get("index").unwrap().as_usize().unwrap(),
            j.get("token").unwrap().as_i64().unwrap() as i32,
        ));
    }
    let done =
        Json::parse(&String::from_utf8(chunks.last().unwrap().clone()).unwrap())
            .unwrap();
    assert!(done.get("done").unwrap().as_bool().unwrap());
    (tokens, done)
}

#[test]
fn streaming_generate_is_bit_identical_to_session_generate() {
    // the solo reference path: Session::generate on the facade
    let session = Session::builder()
        .model_name("smoke_gpt")
        .artifacts_dir(artifacts())
        .dataset_auto()
        .build()
        .unwrap();
    // the server serves the session's exact weights
    let rt = Runtime::load(&artifacts(), "smoke_gpt").unwrap();
    let server = Server::start_with_parts(
        ServeConfig {
            model: "smoke_gpt".into(),
            artifacts_dir: artifacts(),
            port: 0,
            workers: 2,
            batch_window: Duration::from_millis(5),
            ..ServeConfig::default()
        },
        rt,
        session.params().clone(),
        Arc::new(NullSink),
    )
    .unwrap();
    let addr = server.addr();

    // three concurrent streams — different prompts and samplers, so the
    // scheduler has to batch them into shared decode ticks; every stream
    // must still match its solo Session::generate run token-for-token
    let cases: Vec<(Vec<i32>, GenOpts, String)> = vec![
        (
            vec![1, 2],
            GenOpts { max_tokens: 4, ..GenOpts::default() },
            r#"{"prompt": [1, 2], "max_tokens": 4}"#.into(),
        ),
        (
            vec![5],
            GenOpts { max_tokens: 6, ..GenOpts::default() },
            r#"{"prompt": [5], "max_tokens": 6}"#.into(),
        ),
        (
            vec![3, 1, 4],
            GenOpts {
                max_tokens: 5,
                temperature: 0.8,
                top_k: 3,
                seed: 42,
                ..GenOpts::default()
            },
            r#"{"prompt": [3, 1, 4], "max_tokens": 5, "temperature": 0.8, "top_k": 3, "seed": 42}"#
                .into(),
        ),
    ];
    let expected: Vec<_> = cases
        .iter()
        .map(|(p, o, _)| session.generate(p, o).unwrap())
        .collect();

    let handles: Vec<_> = cases
        .iter()
        .map(|(_, _, body)| {
            let body = body.clone();
            std::thread::spawn(move || stream_generate(addr, &body))
        })
        .collect();
    let mut total = 0usize;
    for ((h, want), (prompt, _, _)) in
        handles.into_iter().zip(&expected).zip(&cases)
    {
        let (tokens, done) = h.join().unwrap();
        let streamed: Vec<i32> = tokens.iter().map(|&(_, t)| t).collect();
        assert_eq!(
            streamed, want.tokens,
            "streamed tokens differ from Session::generate"
        );
        // one chunk per token, indexed in decode order
        let indices: Vec<usize> = tokens.iter().map(|&(i, _)| i).collect();
        assert_eq!(indices, (0..want.tokens.len()).collect::<Vec<_>>());
        // terminal summary echoes stop reason, prompt length, full sequence
        assert_eq!(
            done.get("stop").unwrap().as_str().unwrap(),
            want.stop.name()
        );
        assert_eq!(
            done.get("prompt_len").unwrap().as_usize().unwrap(),
            prompt.len()
        );
        let echoed: Vec<i32> = done
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as i32)
            .collect();
        assert_eq!(echoed, want.tokens);
        total += want.tokens.len();
    }

    // /stats gained generation gauges: token totals and active sessions
    let (status, body) = client::get(addr, "/stats").unwrap();
    assert_eq!(status, 200);
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let g = stats.get("generate").unwrap();
    assert_eq!(g.get("tokens").unwrap().as_usize().unwrap(), total);
    assert_eq!(g.get("active_sessions").unwrap().as_usize().unwrap(), 0);
    assert!(g.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);

    server.shutdown().unwrap();
}

/// Train two steps, checkpoint, and hand back (checkpoint path, config).
fn pretrained_ckpt(dir: &Path) -> (PathBuf, TrainConfig) {
    let cfg = cfg_gpt();
    let mut pre = Trainer::new(cfg.clone()).unwrap();
    let ds = dataset_for(&pre.rt, &cfg).unwrap();
    for step in 0..2 {
        pre.train_step(&ds.train_batch(step)).unwrap();
    }
    let ckpt = dir.join("pretrained.ckpt");
    pre.save_checkpoint(&ckpt).unwrap();
    (ckpt, cfg)
}

#[test]
fn init_from_matches_resumed_trainer_bit_exactly() {
    let dir = tmp_dir("init_from");
    let (ckpt, cfg) = pretrained_ckpt(&dir);

    // resume expressed imperatively: fresh trainer + load_checkpoint
    let mut resumed = Trainer::new(cfg.clone()).unwrap();
    resumed.load_checkpoint(&ckpt).unwrap();

    // the same restart expressed as config — plus a new corpus split
    // (datasets are keyed on the seed; params, step, gamma RNG and
    // optimizer moments all come from the checkpoint either way)
    let mut ft_cfg = cfg.clone();
    ft_cfg.init_from = Some(ckpt.clone());
    ft_cfg.seed = 99;
    let mut ft = Trainer::new(ft_cfg.clone()).unwrap();
    assert_eq!(ft.step(), 2, "init_from should restore the step counter");
    assert_eq!(store_bits(&ft.params), store_bits(&resumed.params));
    assert_eq!(ft.rng_gamma_state(), resumed.rng_gamma_state());

    // fine-tune both on the *new* split: every step must stay bit-equal
    let ft_ds = dataset_for(&ft.rt, &ft_cfg).unwrap();
    for step in 2..4 {
        let b = ft_ds.train_batch(step);
        let sa = resumed.train_step(&b).unwrap();
        let sb = ft.train_step(&b).unwrap();
        assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
        assert_eq!(sa.grad_norm.to_bits(), sb.grad_norm.to_bits());
    }
    assert_eq!(
        store_bits(&ft.params),
        store_bits(&resumed.params),
        "init_from fine-tuning diverged from an explicit resume"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn freeze_embed_pins_embedding_while_the_rest_trains() {
    let dir = tmp_dir("freeze");
    let (ckpt, cfg) = pretrained_ckpt(&dir);

    let mut ft_cfg = cfg;
    ft_cfg.init_from = Some(ckpt);
    ft_cfg.freeze_embed = true;
    let mut ft = Trainer::new(ft_cfg.clone()).unwrap();
    let embed0 = group_bits(&ft.params, "embed");
    let head0 = group_bits(&ft.params, "head");

    let ds = dataset_for(&ft.rt, &ft_cfg).unwrap();
    for step in 2..5 {
        ft.train_step(&ds.train_batch(step)).unwrap();
    }
    assert_eq!(
        group_bits(&ft.params, "embed"),
        embed0,
        "frozen embedding moved (optimizer moments must be skipped too, \
         not just gradients)"
    );
    assert_ne!(
        group_bits(&ft.params, "head"),
        head0,
        "unfrozen parameters should keep training"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn fleet_router_declines_generate_with_501() {
    let (rt, params) = reference();
    let router = Router::start_with_parts(
        FleetConfig {
            model: "smoke_gpt".into(),
            artifacts_dir: artifacts(),
            port: 0,
            batch_window: Duration::from_millis(5),
            queue_cap: 0,
            deadline: Duration::from_secs(2),
            ..FleetConfig::default()
        },
        rt,
        params,
        Arc::new(NullSink),
    )
    .unwrap();
    let addr = router.addr();

    let (status, body) =
        client::post(addr, "/generate", br#"{"prompt": [1, 2]}"#).unwrap();
    assert_eq!(status, 501, "fleet generation should answer 501, not route");
    let text = String::from_utf8(body).unwrap();
    assert!(
        text.contains("single-process"),
        "501 body should point at `bdia serve`: {text}"
    );

    // fleet /stats keeps its existing shape — no generation gauges
    let (status, body) = client::get(addr, "/stats").unwrap();
    assert_eq!(status, 200);
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(stats.opt("generate").is_none());

    client::shutdown(addr).unwrap();
    router.join().unwrap();
}
