//! Cross-world-size bit-determinism: the acceptance contract of
//! `bdia::dist`.
//!
//! For every model family, in both reversible and float modes, a training
//! run split across `ranks ∈ {1, 2, 4}` workers (full N-rank worlds
//! assembled **in this process** over loopback TCP) must produce
//! **bit-identical** losses, accuracies, gradient norms and final
//! parameters — identical to each other, to the plain single-process
//! [`Trainer`] consuming the same global batch (`grad_accum` fixed), and
//! across kernel-pool thread counts.  This extends the repo's
//! determinism-by-construction rule from threads (PR 3) to ranks: the
//! collective folds micro-gradients serially in global micro order, and
//! per-micro γ streams are pure functions of the micro index, so world
//! size is — like thread count — purely a speed knob.

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::data::make_dataset;
use bdia::dist::run_local_world;
use bdia::kernels::pool;

/// Everything observable from a short run, as bits.
#[derive(Clone, PartialEq, Eq, Debug)]
struct Signature {
    losses: Vec<u32>,
    accs: Vec<u32>,
    grad_norms: Vec<u32>,
    step: usize,
    params: Vec<u32>,
}

fn bits_of_store(ps: &bdia::model::ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

fn cfg_for(
    model: &str,
    dataset: &str,
    mode: TrainMode,
    ranks: usize,
    grad_accum: usize,
    steps: usize,
) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        dataset: dataset.into(),
        mode,
        steps,
        eval_every: 0,
        log_every: 1,
        train_examples: 64,
        val_examples: 8,
        seed: 7,
        ranks,
        grad_accum,
        ..TrainConfig::default()
    }
}

/// Drive `steps` global optimization steps and snapshot the run.
fn drive(tr: &mut Trainer, steps: usize) -> Signature {
    let cfg = tr.cfg.clone();
    let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family)
        .expect("dataset");
    let mut sig = Signature {
        losses: Vec::new(),
        accs: Vec::new(),
        grad_norms: Vec::new(),
        step: 0,
        params: Vec::new(),
    };
    for _ in 0..steps {
        let s = tr.train_step_global(ds.as_ref()).expect("train_step_global");
        sig.losses.push(s.loss.to_bits());
        sig.accs.push(s.acc.to_bits());
        sig.grad_norms.push(s.grad_norm.to_bits());
    }
    sig.step = tr.step();
    sig.params = bits_of_store(&tr.params);
    sig
}

/// The reference: a plain single-process [`Trainer`], no world attached,
/// consuming the same global batch via the same `grad_accum`.
fn plain_signature(cfg: &TrainConfig) -> Signature {
    let cfg = TrainConfig { ranks: 1, ..cfg.clone() };
    let mut tr = Trainer::new(cfg.clone()).expect("trainer");
    drive(&mut tr, cfg.steps)
}

/// A full `cfg.ranks`-sized world in this process; returns one signature
/// per rank (every rank tracks every stat, so lockstep is observable).
fn world_signatures(cfg: &TrainConfig) -> Vec<Signature> {
    run_local_world(cfg, |_rank, role| {
        let mut tr = Trainer::new(cfg.clone())?;
        tr.attach_dist(role)?;
        Ok(drive(&mut tr, cfg.steps))
    })
    .expect("world run")
}

fn assert_sig_eq(a: &Signature, b: &Signature, what: &str) {
    assert_eq!(a.losses, b.losses, "{what}: losses diverged");
    assert_eq!(a.accs, b.accs, "{what}: accuracies diverged");
    assert_eq!(a.grad_norms, b.grad_norms, "{what}: grad norms diverged");
    assert_eq!(a.step, b.step, "{what}: step counters diverged");
    assert_eq!(a.params, b.params, "{what}: parameters diverged");
}

/// The headline claim: ranks ∈ {1, 2, 4} are bit-identical to each other
/// and to the plain single-process trainer, for all three families, in
/// both reversible and float modes.
#[test]
fn dist_training_bit_identical_across_world_sizes() {
    const ACCUM: usize = 4;
    const STEPS: usize = 2;
    for (model, dataset) in [
        ("smoke_vit", "synth_cifar10"),
        ("smoke_gpt", "tiny_corpus"),
        ("smoke_encdec", "synth_translation"),
    ] {
        for mode in [TrainMode::BdiaReversible, TrainMode::BdiaFloat] {
            let base = plain_signature(&cfg_for(
                model, dataset, mode, 1, ACCUM, STEPS,
            ));
            assert!(
                base.losses.iter().all(|&b| f32::from_bits(b).is_finite()),
                "{model}/{mode:?}: reference run must be finite"
            );
            for ranks in [1usize, 2, 4] {
                let cfg = cfg_for(model, dataset, mode, ranks, ACCUM, STEPS);
                let sigs = world_signatures(&cfg);
                assert_eq!(sigs.len(), ranks);
                for (r, sig) in sigs.iter().enumerate() {
                    assert_sig_eq(
                        sig,
                        &base,
                        &format!("{model}/{mode:?} rank {r}/{ranks} vs plain"),
                    );
                }
            }
        }
    }
}

/// World size composes with thread count: the same signature falls out at
/// every (ranks, kernel threads) combination.
#[test]
fn dist_training_bit_identical_across_thread_counts() {
    const ACCUM: usize = 4;
    let mut sigs = Vec::new();
    for threads in [1usize, 2, 5] {
        pool::set_threads(threads);
        let base = plain_signature(&cfg_for(
            "smoke_gpt",
            "tiny_corpus",
            TrainMode::BdiaReversible,
            1,
            ACCUM,
            2,
        ));
        let cfg = cfg_for(
            "smoke_gpt",
            "tiny_corpus",
            TrainMode::BdiaReversible,
            2,
            ACCUM,
            2,
        );
        let world = world_signatures(&cfg);
        assert_sig_eq(
            &world[0],
            &base,
            &format!("threads={threads}: 2-rank world vs plain"),
        );
        sigs.push(world[0].clone());
    }
    pool::set_threads(0);
    for s in &sigs[1..] {
        assert_sig_eq(s, &sigs[0], "across thread counts");
    }
}

/// Observability is non-interfering across the dist stack too: a 2-rank
/// world with span tracing and metrics fully enabled — collective spans
/// live, clock-sync frames on the wire at attach — bit-matches the plain
/// single-process trainer, and the trainer/collective phases all left
/// spans in the ring.
#[test]
fn dist_training_bit_identical_with_tracing_enabled() {
    const ACCUM: usize = 4;
    let mode = TrainMode::BdiaReversible;
    bdia::obs::set_level(bdia::obs::OFF);
    let base = plain_signature(&cfg_for(
        "smoke_gpt",
        "tiny_corpus",
        mode,
        1,
        ACCUM,
        2,
    ));
    bdia::obs::set_level(bdia::obs::SPANS);
    let cfg = cfg_for("smoke_gpt", "tiny_corpus", mode, 2, ACCUM, 2);
    let sigs = world_signatures(&cfg);
    let (events, _dropped) = bdia::obs::snapshot();
    bdia::obs::set_level(bdia::obs::OFF);
    for (r, sig) in sigs.iter().enumerate() {
        assert_sig_eq(sig, &base, &format!("traced rank {r}/2 vs plain"));
    }
    for want in ["fwd", "bwd", "all_reduce", "optimizer", "dist_reduce"] {
        assert!(
            events.iter().any(|e| e.name == want),
            "no '{want}' span recorded by the traced world"
        );
    }
}

/// `ranks=1, grad_accum=1` through the attached-world path is exactly the
/// legacy single-batch `train_step` — the dist layer costs nothing when
/// it is not used.
#[test]
fn world_of_one_matches_legacy_single_batch_path() {
    let cfg = cfg_for(
        "smoke_vit",
        "synth_cifar10",
        TrainMode::BdiaReversible,
        1,
        1,
        3,
    );
    // legacy loop: explicit per-step batches through train_step
    let mut legacy_tr = Trainer::new(cfg.clone()).unwrap();
    let ds = make_dataset(
        &cfg,
        &legacy_tr.rt.manifest.dims.clone(),
        legacy_tr.family,
    )
    .unwrap();
    let mut legacy = Signature {
        losses: Vec::new(),
        accs: Vec::new(),
        grad_norms: Vec::new(),
        step: 0,
        params: Vec::new(),
    };
    for step in 0..cfg.steps {
        let b = ds.train_batch(step);
        let s = legacy_tr.train_step(&b).unwrap();
        legacy.losses.push(s.loss.to_bits());
        legacy.accs.push(s.acc.to_bits());
        legacy.grad_norms.push(s.grad_norm.to_bits());
    }
    legacy.step = legacy_tr.step();
    legacy.params = bits_of_store(&legacy_tr.params);

    let world = world_signatures(&cfg);
    assert_sig_eq(&world[0], &legacy, "solo world vs legacy train_step");
}

/// Checkpoints are rank 0's: a checkpoint written by a plain run, resumed
/// on rank 0 alone, is broadcast at attach time and the whole world
/// continues bit-identically to an uninterrupted single-process run.
#[test]
fn rank0_resume_broadcasts_state_to_the_world() {
    let dir = std::env::temp_dir()
        .join(format!("bdia_dist_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("mid.ckpt");

    let mode = TrainMode::BdiaReversible;
    // uninterrupted reference: 3 global steps, grad_accum 2
    let full = plain_signature(&cfg_for(
        "smoke_gpt",
        "tiny_corpus",
        mode,
        1,
        2,
        3,
    ));

    // first 2 steps, checkpointed
    let cfg2 = cfg_for("smoke_gpt", "tiny_corpus", mode, 1, 2, 2);
    let mut tr = Trainer::new(cfg2.clone()).unwrap();
    drive(&mut tr, 2);
    tr.save_checkpoint(&ckpt).unwrap();

    // a 2-rank world resumes from rank 0 only and runs the third step
    let cfg_w = cfg_for("smoke_gpt", "tiny_corpus", mode, 2, 2, 3);
    let sigs = run_local_world(&cfg_w, |rank, role| {
        let mut tr = Trainer::new(cfg_w.clone())?;
        if rank == 0 {
            tr.load_checkpoint(&ckpt)?;
        }
        tr.attach_dist(role)?; // broadcasts params/opt/step/γ-RNG
        anyhow::ensure!(tr.step() == 2, "rank {rank} did not receive step 2");
        Ok(drive(&mut tr, 1))
    })
    .unwrap();
    for (r, sig) in sigs.iter().enumerate() {
        assert_eq!(sig.step, 3, "rank {r} step");
        assert_eq!(sig.params, full.params, "rank {r}: resumed world diverged");
        assert_eq!(sig.losses[0], full.losses[2], "rank {r}: step-3 loss");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A world whose config digests disagree must fail the rendezvous, not
/// train quietly on diverged settings.
#[test]
fn mismatched_config_fails_rendezvous() {
    use bdia::dist::{Rendezvous, Transport, WorldSpec};
    let good = cfg_for("smoke_gpt", "tiny_corpus", TrainMode::BdiaReversible, 2, 2, 1);
    let bad = TrainConfig { lr: 3e-4, ..good.clone() };
    let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
    let addr = rdv.addr();
    let deadline = good.dist_deadline();
    let worker = std::thread::spawn(move || {
        Transport::connect(
            addr,
            1,
            &WorldSpec::for_config(&bad),
            std::time::Duration::from_secs(10),
            deadline,
        )
    });
    let hub = rdv.accept(
        &WorldSpec::for_config(&good),
        std::time::Duration::from_secs(10),
        deadline,
    );
    assert!(hub.is_err(), "hub accepted a mismatched config");
    assert!(worker.join().unwrap().is_err());
}

/// grad_accum not divisible by the world size is rejected at attach time.
#[test]
fn indivisible_grad_accum_rejected() {
    let cfg = cfg_for("smoke_gpt", "tiny_corpus", TrainMode::BdiaReversible, 2, 3, 1);
    let err = run_local_world(&cfg, |_rank, role| {
        let mut tr = Trainer::new(cfg.clone())?;
        match tr.attach_dist(role) {
            Ok(()) => anyhow::bail!("accum 3 with world 2 must be rejected"),
            Err(e) => Ok(e.to_string()),
        }
    });
    let msgs = err.unwrap();
    assert!(msgs[0].contains("multiple"), "{}", msgs[0]);
}
