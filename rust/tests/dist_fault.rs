//! Fault tolerance: a dead rank must not hang the world.
//!
//! These tests stage rank deaths inside full in-process worlds (via
//! [`bdia::dist::run_local_world_injected`]) and assert the three-part
//! contract of the failure semantics:
//!
//! 1. **No hang** — every survivor of a killed or wedged rank terminates
//!    with a structured [`DistError`] naming the dead rank, within two
//!    deadlines of the death (a watchdog thread enforces the bound; if the
//!    old eternal-block behaviour regresses, the watchdog panics instead
//!    of the test runner freezing).
//! 2. **No false positives** — a rank that is merely *slow* keeps
//!    heartbeating, so a delay much longer than the deadline aborts
//!    nothing and changes no bits.
//! 3. **Bit-exact recovery** — after a rank dies, rebuilding the world and
//!    re-attaching (the `--on-rank-failure=restart` path) resumes from
//!    rank 0's last completed step and finishes bit-identical to a run
//!    that never failed, for all three model families.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::data::make_dataset;
use bdia::dist::transport::{ACCEPT_TIMEOUT, CONNECT_TIMEOUT};
use bdia::dist::{
    run_local_world_injected, Collective, DistError, DistRole, FaultInjector,
    FaultKind, FaultPlan, Rendezvous, Transport, WorldSpec,
};

// ---------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------

/// Run `f` on a helper thread and panic if it has not finished within
/// `limit`.  This is the no-hang oracle: a regression back to unbounded
/// blocking reads fails loudly here instead of freezing the test binary.
fn with_watchdog<R>(limit: Duration, f: impl FnOnce() -> R + Send + 'static) -> R
where
    R: Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let body = std::thread::spawn(move || {
        let r = f();
        let _ = tx.send(());
        r
    });
    match rx.recv_timeout(limit) {
        Ok(()) => body.join().expect("test body panicked"),
        Err(mpsc::RecvTimeoutError::Disconnected) => match body.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        },
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("distributed world hung — watchdog fired after {limit:?}")
        }
    }
}

/// A config whose world runs raw collectives (no trainer): only the dist
/// shape and the deadline matter.
fn fault_cfg(ranks: usize, dist_timeout_s: f64) -> TrainConfig {
    TrainConfig { ranks, dist_timeout_s, ..TrainConfig::default() }
}

/// Training config for the recovery tests.  `grad_accum` is pinned (the
/// `0 = auto` default resolves to the world size, which would change the
/// global batch between the reference and the world run).
fn train_cfg(
    model: &str,
    dataset: &str,
    ranks: usize,
    steps: usize,
    dist_timeout_s: f64,
) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        dataset: dataset.into(),
        mode: TrainMode::BdiaReversible,
        steps,
        eval_every: 0,
        log_every: 1,
        train_examples: 64,
        val_examples: 8,
        seed: 7,
        ranks,
        grad_accum: 2,
        dist_timeout_s,
        ..TrainConfig::default()
    }
}

fn bits_of_store(ps: &bdia::model::ParamStore) -> Vec<u32> {
    let mut out = Vec::new();
    for insts in ps.groups.values() {
        for inst in insts {
            for t in inst {
                out.extend(t.data().iter().map(|v| v.to_bits()));
            }
        }
    }
    out
}

/// Final parameter bits of a plain single-process run (the reference the
/// recovery tests must hit exactly).
fn plain_param_bits(cfg: &TrainConfig) -> Vec<u32> {
    let cfg = TrainConfig { ranks: 1, ..cfg.clone() };
    let mut tr = Trainer::new(cfg.clone()).expect("trainer");
    let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family)
        .expect("dataset");
    while tr.step() < cfg.steps {
        tr.train_step_global(ds.as_ref()).expect("train_step_global");
    }
    bits_of_store(&tr.params)
}

/// Drive a trainer inside a world, firing the injector at the top of each
/// global step (the same shape the CLI's training loop has).
fn drive_injected(
    cfg: &TrainConfig,
    role: DistRole,
    inject: &FaultInjector,
) -> anyhow::Result<Vec<u32>> {
    let mut tr = Trainer::new(cfg.clone())?;
    tr.attach_dist(role)?;
    let ds = make_dataset(cfg, &tr.rt.manifest.dims.clone(), tr.family)?;
    while tr.step() < cfg.steps {
        let step = tr.step();
        if let Some(coll) = tr.collective_mut() {
            inject.before_step(step, coll)?;
        }
        tr.train_step_global(ds.as_ref())?;
    }
    Ok(bits_of_store(&tr.params))
}

fn dist_error_of(e: &anyhow::Error) -> &DistError {
    e.downcast_ref::<DistError>()
        .unwrap_or_else(|| panic!("expected a DistError, got: {e:#}"))
}

// ---------------------------------------------------------------------
// no-hang: killed and wedged ranks
// ---------------------------------------------------------------------

/// Rank 1 of 3 dies mid-run.  Rank 0 must see the loss directly (EOF on
/// the dead rank's link), rank 2 must learn it via the hub's ABORT relay,
/// and both must error within two deadlines of the death — nobody hangs.
#[test]
fn killed_rank_fails_every_survivor_within_two_deadlines() {
    let deadline = Duration::from_millis(800);
    with_watchdog(Duration::from_secs(30), move || {
        let cfg = fault_cfg(3, deadline.as_secs_f64());
        let plan = FaultPlan { rank: 1, at_step: 1, kind: FaultKind::Kill };
        let killed_at = Arc::new(Mutex::new(None::<Instant>));
        let detected = Arc::new(Mutex::new(Vec::<(usize, Instant)>::new()));
        let (ka, det) = (Arc::clone(&killed_at), Arc::clone(&detected));
        let results = run_local_world_injected(&cfg, plan, move |rank, mut role, inject| {
            let mut acc = vec![0f32; 4];
            for step in 0..4 {
                if let Err(e) = inject.before_step(step, &mut role.coll) {
                    *ka.lock().unwrap() = Some(Instant::now());
                    return Err(e);
                }
                let contrib = vec![rank as f32; 4];
                let r = role
                    .coll
                    .reduce_sum_rank_ordered(&mut acc, &contrib)
                    .and_then(|()| role.coll.broadcast(&mut acc));
                if let Err(e) = r {
                    det.lock().unwrap().push((rank, Instant::now()));
                    return Err(e);
                }
            }
            Ok(())
        })
        .unwrap();

        assert_eq!(results.len(), 3);
        assert!(results[1].is_err(), "rank 1 was staged to die");
        for survivor in [0usize, 2] {
            let err = results[survivor].as_ref().unwrap_err();
            let de = dist_error_of(err);
            assert_eq!(
                de.rank, 1,
                "rank {survivor} must blame rank 1, said: {de}"
            );
        }
        let killed = killed_at.lock().unwrap().expect("rank 1 recorded its death");
        let detected = detected.lock().unwrap();
        assert_eq!(detected.len(), 2, "both survivors must detect the death");
        for &(rank, when) in detected.iter() {
            let lag = when.duration_since(killed);
            assert!(
                lag <= 2 * deadline,
                "rank {rank} took {lag:?} to notice (bound: {:?})",
                2 * deadline
            );
        }
    });
}

/// A wedged rank — alive but silent, heartbeats halted — trips the
/// deadline: the hub's wait is bounded and the error says so.
#[test]
fn wedged_rank_trips_the_deadline_with_a_structured_error() {
    with_watchdog(Duration::from_secs(30), || {
        let cfg = fault_cfg(2, 0.5);
        let plan = FaultPlan {
            rank: 1,
            at_step: 0,
            kind: FaultKind::Wedge(Duration::from_millis(1500)),
        };
        let results = run_local_world_injected(&cfg, plan, |_rank, mut role, inject| {
            let mut acc = vec![0f32; 2];
            inject.before_step(0, &mut role.coll)?;
            role.coll.reduce_sum_rank_ordered(&mut acc, &[1.0, 2.0])?;
            role.coll.broadcast(&mut acc)?;
            Ok(())
        })
        .unwrap();

        assert!(results[1].is_err(), "the wedged rank dies by design");
        let de = dist_error_of(results[0].as_ref().unwrap_err());
        assert_eq!(de.rank, 1, "{de}");
        assert_eq!(de.op, "reduce", "{de}");
        assert!(
            de.elapsed >= Duration::from_millis(400),
            "hub gave up before the deadline: {de}"
        );
        assert!(de.detail.contains("deadline"), "{de}");
    });
}

/// Killing the hub itself must not strand the workers: their next
/// collective sees the closed connection and blames rank 0.
#[test]
fn dead_hub_fails_the_workers_not_hangs_them() {
    with_watchdog(Duration::from_secs(30), || {
        let cfg = fault_cfg(2, 0.8);
        let plan = FaultPlan { rank: 0, at_step: 1, kind: FaultKind::Kill };
        let results = run_local_world_injected(&cfg, plan, |rank, mut role, inject| {
            let mut acc = vec![0f32; 2];
            for step in 0..3 {
                inject.before_step(step, &mut role.coll)?;
                acc.fill(0.0);
                role.coll.reduce_sum_rank_ordered(&mut acc, &[rank as f32; 2])?;
                role.coll.broadcast(&mut acc)?;
            }
            Ok(())
        })
        .unwrap();

        assert!(results[0].is_err(), "rank 0 was staged to die");
        let de = dist_error_of(results[1].as_ref().unwrap_err());
        assert_eq!(de.rank, 0, "the worker must blame the hub: {de}");
    });
}

// ---------------------------------------------------------------------
// no false positives: slow is not dead
// ---------------------------------------------------------------------

/// A 1.2 s stall against a 0.4 s deadline: heartbeats keep flowing, so the
/// world absorbs the delay with no abort and the run stays bit-identical
/// to an undelayed single-process reference.
#[test]
fn delayed_rank_is_not_mistaken_for_dead_and_bits_are_unchanged() {
    let bits = with_watchdog(Duration::from_secs(120), || {
        let cfg = train_cfg("smoke_gpt", "tiny_corpus", 2, 3, 0.4);
        let plan = FaultPlan {
            rank: 1,
            at_step: 1,
            kind: FaultKind::Delay(Duration::from_millis(1200)),
        };
        let results = run_local_world_injected(&cfg, plan, |_rank, role, inject| {
            drive_injected(&cfg, role, &inject)
        })
        .unwrap();
        let per_rank: Vec<Vec<u32>> = results
            .into_iter()
            .map(|r| r.expect("a delayed rank must not abort the world"))
            .collect();
        assert_eq!(per_rank[0], per_rank[1], "world fell out of lockstep");
        (cfg, per_rank.into_iter().next().unwrap())
    });
    let (cfg, world_bits) = bits;
    assert_eq!(
        world_bits,
        plain_param_bits(&cfg),
        "delay changed the numbers"
    );
}

// ---------------------------------------------------------------------
// rendezvous stragglers
// ---------------------------------------------------------------------

/// A world that never fully assembles fails the hub with a progress count
/// instead of blocking in accept forever; the one worker that did join is
/// released, not stranded.
#[test]
fn straggler_rendezvous_fails_cleanly_naming_progress() {
    with_watchdog(Duration::from_secs(30), || {
        let cfg = fault_cfg(3, 1.0);
        let spec = WorldSpec::for_config(&cfg);
        let deadline = cfg.dist_deadline();
        let rdv = Rendezvous::bind("127.0.0.1:0", 3).unwrap();
        let addr = rdv.addr();
        // only rank 1 shows up; rank 2 never will
        let worker = std::thread::spawn(move || {
            Transport::connect(addr, 1, &spec, CONNECT_TIMEOUT, deadline)
        });
        let err = rdv
            .accept(&spec, Duration::from_millis(600), deadline)
            .map(|_| ())
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("1/2"), "no progress count in: {msg}");
        assert!(msg.contains("timed out"), "{msg}");
        // the joined worker got its WELCOME before the hub gave up; either
        // way its connect attempt must have terminated
        let _ = worker.join().unwrap();
    });
}

// ---------------------------------------------------------------------
// bit-exact recovery (the --on-rank-failure=restart path)
// ---------------------------------------------------------------------

/// The restart story end to end, for every model family: a 2-rank world
/// loses rank 1 mid-run, rank 0 detaches (keeping the state of its last
/// completed step), a fresh world assembles, `attach_dist` re-broadcasts
/// rank 0's state, and the run finishes **bit-identical** to a plain
/// single-process run that never saw a failure.
#[test]
fn restart_recovery_is_bit_exact_across_families() {
    for (model, dataset) in [
        ("smoke_vit", "synth_cifar10"),
        ("smoke_gpt", "tiny_corpus"),
        ("smoke_encdec", "synth_translation"),
    ] {
        let (generations, final_step, world_bits, want) =
            with_watchdog(Duration::from_secs(180), move || {
                let cfg = train_cfg(model, dataset, 2, 3, 1.0);
                let want = plain_param_bits(&cfg);
                let spec = WorldSpec::for_config(&cfg);
                let mut tr0 = Trainer::new(cfg.clone()).unwrap();
                let mut fault = Some(FaultPlan {
                    rank: 1,
                    at_step: 1,
                    kind: FaultKind::Kill,
                });
                let mut generations = 0usize;
                while tr0.step() < cfg.steps {
                    generations += 1;
                    assert!(generations <= 3, "{model}: world kept dying");
                    let rdv = Rendezvous::bind("127.0.0.1:0", 2).unwrap();
                    let addr = rdv.addr();
                    let plan = fault.take();
                    let worker = std::thread::spawn({
                        let cfg = cfg.clone();
                        move || -> anyhow::Result<()> {
                            let t = Transport::connect(
                                addr,
                                1,
                                &spec,
                                CONNECT_TIMEOUT,
                                cfg.dist_deadline(),
                            )?;
                            let coll = Collective::new(t, 1, 2)?;
                            let inject = FaultInjector::new(plan, 1);
                            let role = DistRole { rank: 1, world: 2, coll };
                            drive_injected(&cfg, role, &inject)?;
                            Ok(())
                        }
                    });
                    let run0 = (|| -> anyhow::Result<()> {
                        let hub =
                            rdv.accept(&spec, ACCEPT_TIMEOUT, cfg.dist_deadline())?;
                        let coll = Collective::new(hub, 0, 2)?;
                        tr0.attach_dist(DistRole { rank: 0, world: 2, coll })?;
                        let ds = make_dataset(
                            &cfg,
                            &tr0.rt.manifest.dims.clone(),
                            tr0.family,
                        )?;
                        while tr0.step() < cfg.steps {
                            tr0.train_step_global(ds.as_ref())?;
                        }
                        Ok(())
                    })();
                    let _ = worker.join().unwrap();
                    match run0 {
                        Ok(()) => {}
                        Err(e) => {
                            let de = dist_error_of(&e);
                            assert_eq!(de.rank, 1, "{model}: {de}");
                            // rank 0 keeps the last *completed* step; the
                            // next generation re-broadcasts it at attach
                            tr0.detach_dist();
                        }
                    }
                }
                (generations, tr0.step(), bits_of_store(&tr0.params), want)
            });
        assert_eq!(
            generations, 2,
            "{model}: expected exactly one death + one clean restart"
        );
        assert_eq!(final_step, 3, "{model}: recovered run must reach step 3");
        assert_eq!(
            world_bits, want,
            "{model}: recovery is not bit-exact vs the uninterrupted run"
        );
    }
}
