//! Native-backend property tests for the paper's two headline claims, plus
//! finite-difference validation of the hand-written VJPs.
//!
//! 1. **Bit-exact reversibility** (title claim): `forward_quant` →
//!    `reconstruct_all` recovers every intermediate activation bit-for-bit
//!    from the two boundary activations + 1-bit side info, across random
//!    seeds, gamma plans and block counts (eqs. 18-21 / 24).
//! 2. **Ensemble/inference claim** (§4.2): with E[gamma] = 0 at inference,
//!    the BDIA stack collapses to the vanilla transformer forward up to the
//!    documented activation-quantization tolerance (grid step 2^-l).
//! 3. The native executables' VJPs agree with central finite differences —
//!    the gradient math has no JAX oracle here, so the tests carry one.
//!
//! Everything runs on synthesized manifests: no artifacts, no XLA.

use bdia::coordinator::{GammaPlan, Stack, StackKind};
use bdia::model::{Dims, Family, ParamStore};
use bdia::quant;
use bdia::runtime::native::registry::manifest_from_dims;
use bdia::runtime::{ArgValue, Runtime};
use bdia::tensor::{IntTensor, Rng, Tensor};

fn tiny_gpt_dims(n_blocks: usize) -> Dims {
    Dims {
        d_model: 8,
        n_heads: 2,
        n_blocks,
        n_enc_blocks: 0,
        mlp_ratio: 2,
        batch: 2,
        lbits: 9,
        image_size: 32,
        patch: 4,
        channels: 3,
        n_classes: 10,
        seq: 4,
        seq_src: 0,
        vocab: 7,
    }
}

fn gpt_runtime(n_blocks: usize) -> Runtime {
    let m = manifest_from_dims("prop_gpt", Family::Gpt, tiny_gpt_dims(n_blocks));
    Runtime::from_native_manifest(m).expect("native runtime")
}

/// Store-all oracle of the quantized forward (eqs. 18, 19, 21).
fn quant_forward_oracle(
    stack: &Stack,
    params: &ParamStore,
    x0: &Tensor,
    plan: &GammaPlan,
) -> Vec<Tensor> {
    let f = stack.fixed;
    let mut x0q = x0.clone();
    quant::quantize_activation(&mut x0q, f);
    let h0 = stack.debug_call_fwd(params, 0, &x0q, None).unwrap();
    let x1 = quant::first_step_quant(&x0q, &h0, f).unwrap();
    let mut xs = vec![x0q, x1];
    for k in 1..stack.n_blocks {
        let h = stack.debug_call_fwd(params, k, &xs[k], None).unwrap();
        let signs = plan.signs(k).unwrap();
        let (nx, _) =
            quant::bdia_forward_quant(&xs[k - 1], &xs[k], &h, &signs, f).unwrap();
        xs.push(nx);
    }
    xs
}

// ---------------------------------------------------------------------------
// 1. bit-exact reversibility across seeds, plans, block counts
// ---------------------------------------------------------------------------

#[test]
fn prop_forward_quant_reconstructs_bit_identically_across_depths_and_seeds() {
    for n_blocks in [2usize, 3, 5, 8] {
        let rt = gpt_runtime(n_blocks);
        let dims = rt.manifest.dims.clone();
        let stack = Stack::new(&rt, StackKind::Main).unwrap();
        for seed in 0..6u64 {
            let params = ParamStore::init(&rt.manifest, seed ^ 0x5eed);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
            let x0 = Tensor::normal(
                &[dims.batch, dims.seq, dims.d_model],
                1.0 + seed as f32 * 0.5,
                &mut rng,
            );
            let plan = GammaPlan::draw(&mut rng, n_blocks, dims.batch, 0.5);

            let oracle = quant_forward_oracle(&stack, &params, &x0, &plan);
            let state = stack.forward_quant(&params, x0, None, &plan).unwrap();
            let rec = stack.reconstruct_all(&params, &state, None, &plan).unwrap();

            assert_eq!(oracle.len(), rec.len(), "K={n_blocks} seed={seed}");
            for (k, (a, b)) in oracle.iter().zip(&rec).enumerate() {
                assert_eq!(
                    a.data(),
                    b.data(),
                    "x_{k} drifted (K={n_blocks}, seed={seed})"
                );
            }
        }
    }
}

#[test]
fn reversibility_suite_bit_exact_under_thread_pool() {
    // the multi-threaded kernels must not disturb eq. 24 reconstruction:
    // re-run the core reversibility property with the pool engaged at
    // several thread counts (results are thread-count invariant by
    // construction, so the oracle needs computing only once)
    use bdia::kernels::pool;
    let n_blocks = 4usize;
    let rt = gpt_runtime(n_blocks);
    let dims = rt.manifest.dims.clone();
    let stack = Stack::new(&rt, StackKind::Main).unwrap();
    let params = ParamStore::init(&rt.manifest, 0xabcd);
    let mut rng = Rng::new(0x5eed);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, n_blocks, dims.batch, 0.5);

    pool::set_threads(1);
    let oracle = quant_forward_oracle(&stack, &params, &x0, &plan);
    for threads in [2usize, 4, 7] {
        pool::set_threads(threads);
        let state = stack.forward_quant(&params, x0.clone(), None, &plan).unwrap();
        let rec = stack.reconstruct_all(&params, &state, None, &plan).unwrap();
        assert_eq!(oracle.len(), rec.len());
        for (k, (a, b)) in oracle.iter().zip(&rec).enumerate() {
            assert_eq!(
                a.data(),
                b.data(),
                "x_{k} reconstruction drifted at {threads} threads"
            );
        }
    }
    pool::set_threads(0);
}

#[test]
fn prop_online_backward_equals_store_all_across_depths() {
    use bdia::coordinator::StackState;
    for n_blocks in [2usize, 4, 6] {
        let rt = gpt_runtime(n_blocks);
        let dims = rt.manifest.dims.clone();
        let stack = Stack::new(&rt, StackKind::Main).unwrap();
        for seed in 0..3u64 {
            let params = ParamStore::init(&rt.manifest, seed + 100);
            let mut rng = Rng::new(seed + 7);
            let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
            let plan = GammaPlan::draw(&mut rng, n_blocks, dims.batch, 0.5);
            let gx = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

            let state = stack.forward_quant(&params, x0.clone(), None, &plan).unwrap();
            let ga = stack.backward(&params, state, None, &plan, gx.clone()).unwrap();

            let xs = quant_forward_oracle(&stack, &params, &x0, &plan);
            let gb = stack
                .backward(&params, StackState::Full { xs }, None, &plan, gx)
                .unwrap();

            assert_eq!(ga.dx0.data(), gb.dx0.data(), "K={n_blocks} seed={seed}");
            for (da, db) in ga.dparams.iter().zip(&gb.dparams) {
                for (a, b) in da.iter().zip(db) {
                    assert_eq!(a.data(), b.data(), "K={n_blocks} seed={seed}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. ensemble claim: E[gamma] = 0 inference == vanilla forward (+- Q_l)
// ---------------------------------------------------------------------------

#[test]
fn gamma_zero_inference_matches_vanilla_forward_within_quant_tolerance() {
    let rt = gpt_runtime(4);
    let dims = rt.manifest.dims.clone();
    let params = ParamStore::init(&rt.manifest, 11);
    let mut rng = Rng::new(9);
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.below(dims.vocab) as i32)
        .collect();
    let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks).unwrap();

    // vanilla float forward: embed -> plain residual blocks -> head
    let embed = rt.exec("embed_fwd").unwrap();
    let refs = params.refs_for(&embed.spec, 0).unwrap();
    let mut x_f = embed.call(&refs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
    let fwd = rt.exec("block_fwd").unwrap();
    for k in 0..dims.n_blocks {
        let refs = params.refs_for(&fwd.spec, k).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x_f)]).unwrap().remove(0);
        x_f.add_assign(&h).unwrap();
    }
    let head = rt.exec("head_loss_fwd").unwrap();
    let hrefs = params.refs_for(&head.spec, 0).unwrap();
    let outs = head
        .call(&hrefs, &[ArgValue::F32(&x_f), ArgValue::I32(&tokens)])
        .unwrap();
    let loss_float = outs[0].scalar_value().unwrap();

    // quantized E[gamma]=0 inference: same architecture + Q_l only
    let f = quant::Fixed::new(dims.lbits);
    let refs = params.refs_for(&embed.spec, 0).unwrap();
    let mut x_q = embed.call(&refs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
    quant::quantize_activation(&mut x_q, f);
    for k in 0..dims.n_blocks {
        let refs = params.refs_for(&fwd.spec, k).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x_q)]).unwrap().remove(0);
        if k == 0 {
            x_q = quant::first_step_quant(&x_q, &h, f).unwrap();
        } else {
            let mut nx = x_q.clone();
            nx.add_assign(&h).unwrap();
            quant::quantize_activation(&mut nx, f);
            x_q = nx;
        }
    }
    // documented tolerance: one grid step per quantization event, amplified
    // by the (locally ~Lipschitz-1) blocks; (K+1) events in total.
    let step = f.step() as f32;
    let tol_act = (dims.n_blocks + 1) as f32 * step * 8.0;
    let act_diff = x_f.max_abs_diff(&x_q).unwrap();
    assert!(
        act_diff < tol_act,
        "activation divergence {act_diff} exceeds quant tolerance {tol_act}"
    );

    let outs = head
        .call(&hrefs, &[ArgValue::F32(&x_q), ArgValue::I32(&tokens)])
        .unwrap();
    let loss_quant = outs[0].scalar_value().unwrap();
    assert!(
        (loss_float - loss_quant).abs() < 0.05,
        "loss diverged: float {loss_float} vs quantized {loss_quant}"
    );

    // and the fused model_infer executable agrees with the per-block path
    let infer = rt.exec("model_infer").unwrap();
    let irefs = params.refs_for(&infer.spec, 0).unwrap();
    let outs = infer
        .call(
            &irefs,
            &[
                ArgValue::I32(&tokens),
                ArgValue::I32(&tokens),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let loss_fused = outs[0].scalar_value().unwrap();
    assert!(
        (loss_fused - loss_quant).abs() < 1e-5,
        "fused {loss_fused} vs per-block {loss_quant}"
    );
}

// ---------------------------------------------------------------------------
// 3. finite-difference validation of the native VJPs
// ---------------------------------------------------------------------------

/// <g, h(x)> with f64 accumulation (reduces fd noise).
fn dot(g: &Tensor, h: &Tensor) -> f64 {
    g.data()
        .iter()
        .zip(h.data())
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum()
}

fn fd_close(fd: f32, an: f32, what: &str) {
    let tol = 3e-3 + 0.03 * an.abs();
    assert!(
        (fd - an).abs() < tol,
        "{what}: finite difference {fd} vs analytic {an}"
    );
}

#[test]
fn block_vjp_input_grad_matches_finite_difference() {
    let rt = gpt_runtime(2);
    let dims = rt.manifest.dims.clone();
    let ps = ParamStore::init(&rt.manifest, 31);
    let mut rng = Rng::new(13);
    let shape = [dims.batch, dims.seq, dims.d_model];
    let x = Tensor::normal(&shape, 1.0, &mut rng);
    let g = Tensor::normal(&shape, 1.0, &mut rng);

    let vjp = rt.exec("block_vjp").unwrap();
    let refs = ps.refs_for(&vjp.spec, 0).unwrap();
    let outs = vjp
        .call(&refs, &[ArgValue::F32(&x), ArgValue::F32(&g)])
        .unwrap();
    let dx = &outs[1];

    let fwd = rt.exec("block_fwd").unwrap();
    let frefs = ps.refs_for(&fwd.spec, 0).unwrap();
    let probe = |xs: &Tensor| -> f64 {
        let h = fwd.call(&frefs, &[ArgValue::F32(xs)]).unwrap().remove(0);
        dot(&g, &h)
    };
    let eps = 1e-2f32;
    let n = x.len();
    for idx in [0usize, 7, n / 2, n - 1] {
        let mut xp = x.clone();
        xp.data_mut()[idx] += eps;
        let mut xm = x.clone();
        xm.data_mut()[idx] -= eps;
        let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
        fd_close(fd, dx.data()[idx], &format!("block dx[{idx}]"));
    }
}

#[test]
fn block_vjp_param_grads_match_finite_difference() {
    let rt = gpt_runtime(2);
    let dims = rt.manifest.dims.clone();
    let mut ps = ParamStore::init(&rt.manifest, 37);
    let mut rng = Rng::new(17);
    let shape = [dims.batch, dims.seq, dims.d_model];
    let x = Tensor::normal(&shape, 1.0, &mut rng);
    let g = Tensor::normal(&shape, 1.0, &mut rng);

    let vjp = rt.exec("block_vjp").unwrap();
    let fwd = rt.exec("block_fwd").unwrap();
    let grads: Vec<Tensor> = {
        let refs = ps.refs_for(&vjp.spec, 1).unwrap();
        let mut outs = vjp
            .call(&refs, &[ArgValue::F32(&x), ArgValue::F32(&g)])
            .unwrap();
        outs.drain(0..2); // h, dx
        outs
    };

    // leaf indices in the block group: attn.wq = 6, ffn.w1 = 10,
    // ln1.scale = 13, attn.bv = 3 (flatten order)
    for (leaf_idx, probe_elem) in [(6usize, 5usize), (10, 3), (13, 2), (3, 1)] {
        let eps = 1e-2f32;
        let mut run = |delta: f32| -> f64 {
            ps.leaves_mut("block", 1)[leaf_idx].data_mut()[probe_elem] += delta;
            let refs = ps.refs_for(&fwd.spec, 1).unwrap();
            let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
            ps.leaves_mut("block", 1)[leaf_idx].data_mut()[probe_elem] -= delta;
            dot(&g, &h)
        };
        let fd = ((run(eps) - run(-eps)) / (2.0 * eps as f64)) as f32;
        let an = grads[leaf_idx].data()[probe_elem];
        fd_close(fd, an, &format!("block leaf {leaf_idx}[{probe_elem}]"));
    }
}

#[test]
fn head_loss_vjp_matches_finite_difference() {
    let rt = gpt_runtime(2);
    let dims = rt.manifest.dims.clone();
    let mut ps = ParamStore::init(&rt.manifest, 41);
    let mut rng = Rng::new(19);
    let shape = [dims.batch, dims.seq, dims.d_model];
    let x = Tensor::normal(&shape, 1.0, &mut rng);
    let labels = IntTensor::from_vec(
        &[dims.batch, dims.seq],
        (0..dims.batch * dims.seq)
            .map(|i| (i % dims.vocab) as i32)
            .collect(),
    )
    .unwrap();

    let vjp = rt.exec("head_loss_vjp").unwrap();
    let refs = ps.refs_for(&vjp.spec, 0).unwrap();
    let outs = vjp
        .call(&refs, &[ArgValue::F32(&x), ArgValue::I32(&labels)])
        .unwrap();
    let dx = outs[0].clone();
    let dw = outs[4].clone(); // head leaf order: b, ln_f.bias, ln_f.scale, w

    let fwd = rt.exec("head_loss_fwd").unwrap();
    let eps = 1e-2f32;
    // input gradient
    {
        let refs = ps.refs_for(&fwd.spec, 0).unwrap();
        let probe = |xs: &Tensor| -> f64 {
            fwd.call(&refs, &[ArgValue::F32(xs), ArgValue::I32(&labels)])
                .unwrap()[0]
                .scalar_value()
                .unwrap() as f64
        };
        let n = x.len();
        for idx in [0usize, n / 3, n - 1] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let fd = ((probe(&xp) - probe(&xm)) / (2.0 * eps as f64)) as f32;
            fd_close(fd, dx.data()[idx], &format!("head dx[{idx}]"));
        }
    }
    // w gradient (leaf 3)
    for elem in [0usize, 9, 20] {
        let mut run = |delta: f32| -> f64 {
            ps.leaves_mut("head", 0)[3].data_mut()[elem] += delta;
            let refs = ps.refs_for(&fwd.spec, 0).unwrap();
            let l = fwd
                .call(&refs, &[ArgValue::F32(&x), ArgValue::I32(&labels)])
                .unwrap()[0]
                .scalar_value()
                .unwrap() as f64;
            ps.leaves_mut("head", 0)[3].data_mut()[elem] -= delta;
            l
        };
        let fd = ((run(eps) - run(-eps)) / (2.0 * eps as f64)) as f32;
        fd_close(fd, dw.data()[elem], &format!("head dw[{elem}]"));
    }
}

#[test]
fn embed_vjp_matches_finite_difference() {
    let rt = gpt_runtime(2);
    let dims = rt.manifest.dims.clone();
    let mut ps = ParamStore::init(&rt.manifest, 43);
    let mut rng = Rng::new(23);
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.below(dims.vocab) as i32)
        .collect();
    let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks.clone()).unwrap();
    let g = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

    let vjp = rt.exec("embed_vjp").unwrap();
    let refs = ps.refs_for(&vjp.spec, 0).unwrap();
    let outs = vjp
        .call(&refs, &[ArgValue::I32(&tokens), ArgValue::F32(&g)])
        .unwrap();
    assert_eq!(outs.len(), 2); // dwpe, dwte
    let dwte = outs[1].clone();

    // probe a wte row that is actually used
    let used_id = toks[0] as usize;
    let fwd = rt.exec("embed_fwd").unwrap();
    let eps = 1e-2f32;
    for j in 0..dims.d_model {
        let elem = used_id * dims.d_model + j;
        let mut run = |delta: f32| -> f64 {
            ps.leaves_mut("embed", 0)[1].data_mut()[elem] += delta;
            let refs = ps.refs_for(&fwd.spec, 0).unwrap();
            let x = fwd.call(&refs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
            ps.leaves_mut("embed", 0)[1].data_mut()[elem] -= delta;
            dot(&g, &x)
        };
        let fd = ((run(eps) - run(-eps)) / (2.0 * eps as f64)) as f32;
        fd_close(fd, dwte.data()[elem], &format!("dwte[{elem}]"));
    }
}

#[test]
fn encdec_native_train_step_descends_and_routes_dmem() {
    // one end-to-end encdec step on the native backend exercises the
    // cross-attention vjp + dmem accumulation path
    use bdia::config::{TrainConfig, TrainMode};
    use bdia::coordinator::Trainer;
    use bdia::data::make_dataset;
    let cfg = TrainConfig {
        model: "smoke_encdec".into(),
        mode: TrainMode::BdiaReversible,
        dataset: "synth_translation".into(),
        steps: 3,
        eval_every: 0,
        log_every: 1,
        train_examples: 32,
        val_examples: 8,
        lr: 1e-3,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg.clone()).unwrap();
    let ds = make_dataset(&cfg, &tr.rt.manifest.dims.clone(), tr.family).unwrap();
    let mut first = None;
    for step in 0..cfg.steps {
        let b = ds.train_batch(step);
        let s = tr.train_step(&b).unwrap();
        assert!(s.loss.is_finite() && s.grad_norm > 0.0);
        first.get_or_insert(s.loss);
    }
    let b0 = ds.train_batch(0);
    let fs = tr.forward(&b0).unwrap();
    assert!(fs.loss < first.unwrap() + 0.1, "encdec did not descend");
}
