//! Serving integration over real sockets: concurrent requests coalesce into
//! micro-batches, every response is bit-identical to a direct
//! `model_infer_ex` call, the health/stats endpoints answer, shutdown is
//! graceful, and malformed requests get 4xx instead of a worker panic.

use bdia::config::json::Json;
use bdia::model::ParamStore;
use bdia::runtime::Runtime;
use bdia::serve::wire::Example;
use bdia::serve::{client, wire, ServeConfig, Server};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn artifacts() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn start(model: &str, workers: usize, window: Duration) -> Server {
    Server::start(ServeConfig {
        model: model.into(),
        artifacts_dir: artifacts(),
        port: 0,
        workers,
        batch_window: window,
        ..ServeConfig::default()
    })
    .expect("server start")
}

/// Local reference runtime + the same seed-0 params the ckpt-less server
/// initializes.
fn reference(model: &str) -> (Runtime, ParamStore) {
    let rt = Runtime::load(&artifacts(), model).unwrap();
    let params = ParamStore::init(&rt.manifest, 0);
    (rt, params)
}

fn gpt_example(i: usize, seq: usize, vocab: usize) -> Example {
    let tokens: Vec<i32> =
        (0..seq).map(|j| ((i * 7 + j * 3 + 1) % vocab) as i32).collect();
    let labels: Vec<i32> =
        (0..seq).map(|j| ((i * 5 + j * 2 + 2) % vocab) as i32).collect();
    Example::Tok { tokens, labels }
}

#[test]
fn concurrent_requests_are_bit_identical_to_direct_inference() {
    let (rt, params) = reference("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let server = start("smoke_gpt", 4, Duration::from_millis(30));
    let addr = server.addr();

    let n = 12usize;
    let examples: Vec<Example> =
        (0..n).map(|i| gpt_example(i, dims.seq, dims.vocab)).collect();
    let expected: Vec<(f32, f32)> = examples
        .iter()
        .map(|e| wire::infer_one(&rt, &params, e, 0.0).unwrap())
        .collect();

    // fire all requests concurrently over real TcpStreams
    let handles: Vec<_> = examples
        .iter()
        .map(|e| {
            let body = wire::encode(e, 0.0);
            std::thread::spawn(move || client::infer(addr, &body).unwrap())
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&expected) {
        let (loss, correct) = h.join().unwrap();
        assert_eq!(
            loss.to_bits(),
            want.0.to_bits(),
            "served loss differs from direct model_infer_ex"
        );
        assert_eq!(correct.to_bits(), want.1.to_bits());
    }

    // health + stats endpoints
    let (status, body) = client::get(addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    let health = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(health.get("model").unwrap().as_str().unwrap(), "smoke_gpt");

    let (status, body) = client::get(addr, "/stats").unwrap();
    assert_eq!(status, 200);
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), n);
    assert_eq!(stats.get("errors").unwrap().as_usize().unwrap(), 0);
    let batches = stats.get("batches").unwrap().as_usize().unwrap();
    assert!(batches >= 1 && batches <= n, "batches {batches}");
    // per-exec call counts surface through /stats
    assert_eq!(
        stats
            .get("exec_calls")
            .unwrap()
            .get("model_infer_ex")
            .unwrap()
            .as_usize()
            .unwrap(),
        batches
    );

    // graceful shutdown: server drains and the port closes
    client::shutdown(addr).unwrap();
    server.join().unwrap();
    assert!(client::get(addr, "/healthz").is_err(), "port should be closed");
}

#[test]
fn single_worker_under_load_coalesces_batches() {
    // one worker + a wide window: concurrent requests must share
    // executable calls (smoke_gpt's manifest batch is 2, so 8 requests
    // need at most 4 + first-pop singleton batches, strictly < 8)
    let server = start("smoke_gpt", 1, Duration::from_millis(300));
    let addr = server.addr();
    let (rt, _) = reference("smoke_gpt");
    let dims = rt.manifest.dims.clone();

    let n = 8usize;
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let body = wire::encode(&gpt_example(i, dims.seq, dims.vocab), 0.5);
            std::thread::spawn(move || client::infer(addr, &body).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let (_, body) = client::get(addr, "/stats").unwrap();
    let stats = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let batches = stats.get("batches").unwrap().as_usize().unwrap();
    let mean_batch = stats.get("mean_batch").unwrap().as_f64().unwrap();
    assert!(
        batches < n,
        "8 concurrent requests through 1 worker should coalesce, got \
         {batches} batches"
    );
    assert!(mean_batch > 1.0, "mean batch {mean_batch} — batching never engaged");

    client::shutdown(addr).unwrap();
    server.join().unwrap();
}

#[test]
fn vit_and_encdec_families_serve_bit_exactly() {
    // ViT
    {
        let (rt, params) = reference("smoke_vit");
        let d = rt.manifest.dims.clone();
        let server = start("smoke_vit", 2, Duration::from_millis(5));
        let px = d.channels * d.image_size * d.image_size;
        let ex = Example::Vit {
            image: (0..px).map(|i| (i as f32 * 0.37).sin() * 0.5).collect(),
            label: 1,
        };
        let want = wire::infer_one(&rt, &params, &ex, 0.0).unwrap();
        let got = client::infer(server.addr(), &wire::encode(&ex, 0.0)).unwrap();
        assert_eq!(got.0.to_bits(), want.0.to_bits());
        assert_eq!(got.1.to_bits(), want.1.to_bits());
        server.shutdown().unwrap();
    }
    // encoder-decoder
    {
        let (rt, params) = reference("smoke_encdec");
        let d = rt.manifest.dims.clone();
        let server = start("smoke_encdec", 2, Duration::from_millis(5));
        let ex = Example::Seq {
            src: (0..d.seq_src).map(|j| ((j * 3 + 1) % d.vocab) as i32).collect(),
            tgt_in: (0..d.seq).map(|j| ((j * 2 + 2) % d.vocab) as i32).collect(),
            labels: (0..d.seq).map(|j| ((j + 3) % d.vocab) as i32).collect(),
        };
        let want = wire::infer_one(&rt, &params, &ex, 0.5).unwrap();
        let got = client::infer(server.addr(), &wire::encode(&ex, 0.5)).unwrap();
        assert_eq!(got.0.to_bits(), want.0.to_bits());
        assert_eq!(got.1.to_bits(), want.1.to_bits());
        server.shutdown().unwrap();
    }
}

#[test]
fn malformed_requests_get_4xx_not_a_crash() {
    let server = start("smoke_gpt", 1, Duration::from_millis(1));
    let addr = server.addr();

    // wrong body length
    let (status, _) = client::post(addr, "/infer", b"\x00\x01").unwrap();
    assert_eq!(status, 400);
    // out-of-range token ids
    let (rt, _) = reference("smoke_gpt");
    let d = rt.manifest.dims.clone();
    let bad = Example::Tok {
        tokens: vec![d.vocab as i32 + 5; d.seq],
        labels: vec![0; d.seq],
    };
    let (status, body) = client::post(addr, "/infer", &wire::encode(&bad, 0.0)).unwrap();
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("out of range"));
    // unknown endpoint
    let (status, _) = client::get(addr, "/nope").unwrap();
    assert_eq!(status, 404);

    // the server is still healthy after all that abuse
    let ok = gpt_example(0, d.seq, d.vocab);
    client::infer(addr, &wire::encode(&ok, 0.0)).unwrap();
    server.shutdown().unwrap();
}

#[test]
fn bench_serve_self_hosted_end_to_end() {
    // the acceptance-criteria path: 4-worker server, concurrent load,
    // batching engaged, responses verified bit-identical
    let opts = bdia::serve::bench::BenchOpts {
        model: "smoke_gpt".into(),
        artifacts_dir: artifacts(),
        workers: 4,
        requests: 24,
        concurrency: 8,
        batch_window: Duration::from_millis(100),
        ..Default::default()
    };
    let summary = bdia::serve::bench::run(&opts).unwrap();
    assert_eq!(summary.requests, 24);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.mismatches, 0, "serving must be bit-exact");
    assert!(
        summary.mean_batch > 1.0,
        "dynamic batching should engage under concurrent load \
         (mean batch {})",
        summary.mean_batch
    );
}
