//! Integration: load a bundle, execute components, check shapes and
//! cross-layer semantics (host quant pipeline vs the fused inference path).
//!
//! Runs hermetically on the native backend — no artifacts, no XLA.  When an
//! AOT bundle exists on disk its manifest is used instead (identical ABI);
//! cross-backend consistency assertions are gated behind the `pjrt` feature.

use bdia::model::Family;
use bdia::model::ParamStore;
use bdia::runtime::{ArgValue, Runtime};
use bdia::tensor::{IntTensor, Rng, Tensor};
use std::path::Path;

fn load(bundle: &str) -> Runtime {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir, bundle).expect("load bundle")
}

#[test]
fn smoke_gpt_block_fwd_and_vjp() {
    let rt = load("smoke_gpt");
    assert_eq!(rt.manifest.family, Family::Gpt);
    let dims = &rt.manifest.dims;
    let ps = ParamStore::init(&rt.manifest, 42);
    let mut rng = Rng::new(0);
    let x = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);

    let fwd = rt.exec("block_fwd").unwrap();
    let refs = ps.refs_for(&fwd.spec, 0).unwrap();
    let outs = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap();
    assert_eq!(outs.len(), 1);
    let h = &outs[0];
    assert_eq!(h.shape(), x.shape());
    assert!(h.data().iter().all(|v| v.is_finite()));
    assert!(h.max_abs() > 0.0);

    // determinism: the reversibility contract requires identical recompute
    let outs2 = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap();
    assert_eq!(h.data(), outs2[0].data(), "block_fwd must be deterministic");

    // vjp returns (h, dx, dparams...) with h matching block_fwd exactly
    let vjp = rt.exec("block_vjp").unwrap();
    let refs = ps.refs_for(&vjp.spec, 0).unwrap();
    let g = Tensor::ones(&[dims.batch, dims.seq, dims.d_model]);
    let vouts = vjp
        .call(&refs, &[ArgValue::F32(&x), ArgValue::F32(&g)])
        .unwrap();
    let nb = rt.manifest.param_groups["block"].len();
    assert_eq!(vouts.len(), 2 + nb);
    assert_eq!(vouts[0].data(), h.data(), "vjp primal == fwd");
    assert_eq!(vouts[1].shape(), x.shape()); // dx
}

#[test]
fn smoke_gpt_end_to_end_pipeline() {
    let rt = load("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let ps = ParamStore::init(&rt.manifest, 1);
    let mut rng = Rng::new(3);
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.below(dims.vocab) as i32)
        .collect();
    let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks).unwrap();

    // embed -> blocks (plain residual) -> head_loss
    let embed = rt.exec("embed_fwd").unwrap();
    let refs = ps.refs_for(&embed.spec, 0).unwrap();
    let x0 = embed.call(&refs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
    assert_eq!(x0.shape(), &[dims.batch, dims.seq, dims.d_model]);

    let fwd = rt.exec("block_fwd").unwrap();
    let mut x = x0;
    for k in 0..dims.n_blocks {
        let refs = ps.refs_for(&fwd.spec, k).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        x.add_assign(&h).unwrap();
    }

    let head = rt.exec("head_loss_fwd").unwrap();
    let refs = ps.refs_for(&head.spec, 0).unwrap();
    let outs = head
        .call(&refs, &[ArgValue::F32(&x), ArgValue::I32(&tokens)])
        .unwrap();
    let loss = outs[0].scalar_value().unwrap();
    let ncorrect = outs[1].scalar_value().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // random init: loss near ln(vocab)
    let uniform = (dims.vocab as f32).ln();
    assert!((loss - uniform).abs() < 1.5, "loss {loss} vs ln(V) {uniform}");
    assert!((0.0..=(dims.batch * dims.seq) as f32).contains(&ncorrect));
}

#[test]
fn smoke_model_infer_gamma_zero_vs_rust_quant_pipeline() {
    // Cross-layer exactness: the fused inference path (eq. 18/19/21) must
    // agree with the per-block host quantized pipeline (eq. 18/19/22) at
    // gamma = 0 — on any backend.
    let rt = load("smoke_gpt");
    let dims = rt.manifest.dims.clone();
    let f = bdia::quant::Fixed::new(dims.lbits);
    let ps = ParamStore::init(&rt.manifest, 9);
    let mut rng = Rng::new(5);
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.below(dims.vocab) as i32)
        .collect();
    let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks).unwrap();

    // fused path
    let infer = rt.exec("model_infer").unwrap();
    let refs = ps.refs_for(&infer.spec, 0).unwrap();
    let outs = infer
        .call(
            &refs,
            &[
                ArgValue::I32(&tokens),
                ArgValue::I32(&tokens),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let loss_fused = outs[0].scalar_value().unwrap();

    // rust per-block path (eq. 18/19/22)
    let embed = rt.exec("embed_fwd").unwrap();
    let refs = ps.refs_for(&embed.spec, 0).unwrap();
    let mut x = embed.call(&refs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
    bdia::quant::quantize_activation(&mut x, f); // eq. 18
    let fwd = rt.exec("block_fwd").unwrap();
    for k in 0..dims.n_blocks {
        let refs = ps.refs_for(&fwd.spec, k).unwrap();
        let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
        if k == 0 {
            x = bdia::quant::first_step_quant(&x, &h, f).unwrap(); // eq. 19
        } else {
            // eq. 22: x <- Q[x + h]
            let mut nx = x.clone();
            nx.add_assign(&h).unwrap();
            bdia::quant::quantize_activation(&mut nx, f);
            x = nx;
        }
    }
    let head = rt.exec("head_loss_fwd").unwrap();
    let refs = ps.refs_for(&head.spec, 0).unwrap();
    let outs = head
        .call(&refs, &[ArgValue::F32(&x), ArgValue::I32(&tokens)])
        .unwrap();
    let loss_rust = outs[0].scalar_value().unwrap();

    assert!(
        (loss_fused - loss_rust).abs() < 1e-5,
        "fused {loss_fused} vs rust-pipeline {loss_rust}"
    );
}

#[test]
fn smoke_vit_pipeline() {
    let rt = load("smoke_vit");
    let dims = rt.manifest.dims.clone();
    let tokens = dims.tokens(Family::Vit);
    let ps = ParamStore::init(&rt.manifest, 2);
    let mut rng = Rng::new(7);
    let images = Tensor::normal(
        &[dims.batch, dims.channels, dims.image_size, dims.image_size],
        1.0,
        &mut rng,
    );
    let labels = IntTensor::from_vec(
        &[dims.batch],
        (0..dims.batch).map(|i| (i % dims.n_classes) as i32).collect(),
    )
    .unwrap();

    let embed = rt.exec("embed_fwd").unwrap();
    let refs = ps.refs_for(&embed.spec, 0).unwrap();
    let x = embed.call(&refs, &[ArgValue::F32(&images)]).unwrap().remove(0);
    assert_eq!(x.shape(), &[dims.batch, tokens, dims.d_model]);

    let infer = rt.exec("model_infer").unwrap();
    let refs = ps.refs_for(&infer.spec, 0).unwrap();
    let outs = infer
        .call(
            &refs,
            &[
                ArgValue::F32(&images),
                ArgValue::I32(&labels),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let loss = outs[0].scalar_value().unwrap();
    assert!((loss - (dims.n_classes as f32).ln()).abs() < 1.0);
}

#[test]
fn smoke_encdec_block_vjp_returns_dmem() {
    let rt = load("smoke_encdec");
    let dims = rt.manifest.dims.clone();
    let ps = ParamStore::init(&rt.manifest, 11);
    let mut rng = Rng::new(13);
    let x = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let mem = Tensor::normal(&[dims.batch, dims.seq_src, dims.d_model], 1.0, &mut rng);
    let g = Tensor::ones(&[dims.batch, dims.seq, dims.d_model]);

    let vjp = rt.exec("block_vjp").unwrap();
    let refs = ps.refs_for(&vjp.spec, 0).unwrap();
    let outs = vjp
        .call(
            &refs,
            &[ArgValue::F32(&x), ArgValue::F32(&mem), ArgValue::F32(&g)],
        )
        .unwrap();
    let nb = rt.manifest.param_groups["block"].len();
    assert_eq!(outs.len(), 3 + nb); // h, dx, dmem, dparams
    assert_eq!(outs[2].shape(), mem.shape());
    assert!(outs[2].max_abs() > 0.0, "cross-attention must feed dmem");
}

/// Cross-backend consistency: the native interpreter must agree with the
/// compiled AOT artifacts up to f32 reassociation noise.  Only meaningful
/// when the pjrt feature (and artifacts) are available.
#[cfg(feature = "pjrt")]
#[test]
fn native_matches_pjrt_block_forward() {
    use bdia::runtime::BackendKind;
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("smoke_gpt").join("manifest.json").exists() {
        eprintln!("skipping: artifacts/smoke_gpt not built");
        return;
    }
    let nat = Runtime::load_with(&dir, "smoke_gpt", BackendKind::Native).unwrap();
    let pjr = Runtime::load_with(&dir, "smoke_gpt", BackendKind::Pjrt).unwrap();
    let dims = nat.manifest.dims.clone();
    let ps = ParamStore::init(&nat.manifest, 21);
    let mut rng = Rng::new(17);
    let x = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let hn = {
        let e = nat.exec("block_fwd").unwrap();
        let refs = ps.refs_for(&e.spec, 0).unwrap();
        e.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0)
    };
    let hp = {
        let e = pjr.exec("block_fwd").unwrap();
        let refs = ps.refs_for(&e.spec, 0).unwrap();
        e.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0)
    };
    assert!(
        hn.max_abs_diff(&hp).unwrap() < 1e-4,
        "native vs pjrt block_fwd diverged"
    );
}
