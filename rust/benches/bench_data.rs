//! Synthetic-data substrate throughput: batch generation must never starve
//! the trainer (compare against block_fwd latency in bench_block).

use bdia::bench::{bench, default_budget};
use bdia::config::TrainConfig;
use bdia::data::make_dataset;
use bdia::model::{Dims, Family};

fn dims(family: Family) -> Dims {
    Dims {
        d_model: 64,
        n_heads: 4,
        n_blocks: 6,
        n_enc_blocks: 6,
        mlp_ratio: 2,
        batch: 64,
        lbits: 9,
        image_size: 32,
        patch: 4,
        channels: 3,
        n_classes: 10,
        seq: if family == Family::EncDec { 24 } else { 64 },
        seq_src: 24,
        vocab: if family == Family::EncDec { 64 } else { 96 },
    }
}

fn main() {
    for (name, family) in [
        ("synth_cifar10", Family::Vit),
        ("tiny_corpus", Family::Gpt),
        ("synth_translation", Family::EncDec),
    ] {
        let cfg = TrainConfig { dataset: name.into(), ..TrainConfig::default() };
        let d = dims(family);
        let ds = make_dataset(&cfg, &d, family).unwrap();
        let mut step = 0usize;
        let r = bench(&format!("train_batch[{name}]"), 2, 500, default_budget(), || {
            let b = ds.train_batch(step);
            std::hint::black_box(b);
            step += 1;
        });
        println!(
            "{}  ({:.1} examples/s)",
            r.row(),
            r.per_sec(d.batch as f64)
        );
    }
}
