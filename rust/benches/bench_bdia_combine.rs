//! Host fixed-point BDIA combine (eq. 21 + parity extraction, eq. 20): the
//! per-block host cost the coordinator adds over the HLO call.  Reported in
//! elements/s; must stay a small fraction of block_fwd time.

use bdia::bench::{bench, default_budget};
use bdia::quant::{self, Fixed};
use bdia::tensor::{Rng, Tensor};

fn main() {
    let f = Fixed::new(9);
    for (b, t, d) in [(64usize, 65usize, 64usize), (16, 64, 64), (8, 128, 256)] {
        let mut rng = Rng::new(0);
        let mut xp = Tensor::normal(&[b, t * d], 2.0, &mut rng);
        let mut x = Tensor::normal(&[b, t * d], 2.0, &mut rng);
        let h = Tensor::normal(&[b, t * d], 1.0, &mut rng);
        f.quantize_slice(xp.data_mut());
        f.quantize_slice(x.data_mut());
        let signs: Vec<i8> = (0..b).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let elems = (b * t * d) as f64;

        let r = bench(
            &format!("bdia_forward_quant B{b} T{t} D{d}"),
            2,
            200,
            default_budget(),
            || {
                quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
            },
        );
        println!("{}  ({:.1} Melem/s)", r.row(), r.per_sec(elems) / 1e6);

        let gammas: Vec<f32> = signs.iter().map(|&s| 0.5 * s as f32).collect();
        let r = bench(
            &format!("bdia_forward_float B{b} T{t} D{d}"),
            2,
            200,
            default_budget(),
            || {
                quant::bdia_forward_float(&xp, &x, &h, &gammas).unwrap();
            },
        );
        println!("{}  ({:.1} Melem/s)", r.row(), r.per_sec(elems) / 1e6);
    }
}
