//! eq.-24 exact reconstruction throughput — the extra host work online
//! backprop does per block in exchange for not storing activations.

use bdia::bench::{bench, default_budget};
use bdia::quant::{self, Fixed};
use bdia::tensor::{Rng, Tensor};

fn main() {
    let f = Fixed::new(9);
    for (b, t, d) in [(64usize, 65usize, 64usize), (16, 64, 64), (8, 128, 256)] {
        let mut rng = Rng::new(0);
        let mut xp = Tensor::normal(&[b, t * d], 2.0, &mut rng);
        let mut x = Tensor::normal(&[b, t * d], 2.0, &mut rng);
        let h = Tensor::normal(&[b, t * d], 1.0, &mut rng);
        f.quantize_slice(xp.data_mut());
        f.quantize_slice(x.data_mut());
        let signs: Vec<i8> = (0..b).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        let (xn, bits) = quant::bdia_forward_quant(&xp, &x, &h, &signs, f).unwrap();
        let elems = (b * t * d) as f64;

        let r = bench(
            &format!("bdia_reconstruct_quant B{b} T{t} D{d}"),
            2,
            200,
            default_budget(),
            || {
                let rec =
                    quant::bdia_reconstruct_quant(&xn, &x, &h, &bits, &signs, f).unwrap();
                std::hint::black_box(rec);
            },
        );
        println!("{}  ({:.1} Melem/s)", r.row(), r.per_sec(elems) / 1e6);

        // adjoint host ops that accompany it in the backward loop
        let gammas: Vec<f32> = signs.iter().map(|&s| 0.5 * s as f32).collect();
        let mut acc = Tensor::zeros(&[b, t * d]);
        let r = bench(
            &format!("adjoint scale+axpy  B{b} T{t} D{d}"),
            2,
            200,
            default_budget(),
            || {
                let s = quant::scale_rows(&h, &gammas).unwrap();
                quant::axpy_rows(&mut acc, &gammas, &s).unwrap();
            },
        );
        println!("{}  ({:.1} Melem/s)", r.row(), r.per_sec(elems) / 1e6);
    }
}
