//! Inference-path latency: the fused `model_infer` executable (L1 Pallas
//! quantized-update kernels inside one HLO) vs the per-block Rust pipeline —
//! quantifies what fusing the whole forward buys at eval time.

use bdia::bench::{bench, default_budget};
use bdia::model::ParamStore;
use bdia::quant;
use bdia::runtime::{ArgValue, Runtime};
use bdia::tensor::{IntTensor, Rng, Tensor};
use std::path::Path;

fn main() {
    // native backend needs no artifacts (pjrt path loads them when present)
    let art = Path::new("artifacts");
    let bundle = "gpt_tiny";
    let rt = Runtime::load(art, bundle).expect("load");
    let dims = rt.manifest.dims.clone();
    let f = quant::Fixed::new(dims.lbits);
    let ps = ParamStore::init(&rt.manifest, 0);
    let mut rng = Rng::new(0);
    let toks: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|_| rng.below(dims.vocab) as i32)
        .collect();
    let tokens = IntTensor::from_vec(&[dims.batch, dims.seq], toks).unwrap();
    let n_tok = (dims.batch * dims.seq) as f64;

    // fused path
    let infer = rt.exec("model_infer").unwrap();
    let refs = ps.refs_for(&infer.spec, 0).unwrap();
    let r = bench("model_infer (fused, gamma input)", 2, 30, default_budget(), || {
        infer
            .call(
                &refs,
                &[
                    ArgValue::I32(&tokens),
                    ArgValue::I32(&tokens),
                    ArgValue::Scalar(0.0),
                ],
            )
            .unwrap();
    });
    println!("{}  ({:.0} tok/s)", r.row(), r.per_sec(n_tok));

    // per-block Rust pipeline (eqs. 18/19/22 on the host)
    let embed = rt.exec("embed_fwd").unwrap();
    let erefs = ps.refs_for(&embed.spec, 0).unwrap();
    let fwd = rt.exec("block_fwd").unwrap();
    let head = rt.exec("head_loss_fwd").unwrap();
    let hrefs = ps.refs_for(&head.spec, 0).unwrap();
    let r = bench("per-block pipeline (host quant)", 1, 20, default_budget(), || {
        let mut x = embed.call(&erefs, &[ArgValue::I32(&tokens)]).unwrap().remove(0);
        quant::quantize_activation(&mut x, f);
        for k in 0..dims.n_blocks {
            let refs = ps.refs_for(&fwd.spec, k).unwrap();
            let h = fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap().remove(0);
            if k == 0 {
                x = quant::first_step_quant(&x, &h, f).unwrap();
            } else {
                let mut nx = x.clone();
                nx.add_assign(&h).unwrap();
                quant::quantize_activation(&mut nx, f);
                x = nx;
            }
        }
        head.call(&hrefs, &[ArgValue::F32(&x), ArgValue::I32(&tokens)]).unwrap();
    });
    println!("{}  ({:.0} tok/s)", r.row(), r.per_sec(n_tok));

    // Fig.-1 sweep cost: gamma is a runtime input, so the sweep reuses ONE
    // compiled executable — bench a nonzero gamma to show parity.
    let r = bench("model_infer (gamma=0.3)", 2, 30, default_budget(), || {
        infer
            .call(
                &refs,
                &[
                    ArgValue::I32(&tokens),
                    ArgValue::I32(&tokens),
                    ArgValue::Scalar(0.3),
                ],
            )
            .unwrap();
    });
    println!("{}  ({:.0} tok/s)", r.row(), r.per_sec(n_tok));
}
