//! Table-1 systems comparison as a per-step microbench: full training-step
//! latency + live stored-activation bytes for the three training systems
//! (vanilla ViT, RevViT, BDIA-reversible) on the vit_s10 bundle.
//!
//! The paper's Table 1 reports accuracy (see `bdia repro table1`) and peak
//! memory; this bench adds the runtime dimension: what online backprop
//! costs per step in exchange for the memory reduction.

use bdia::baseline::RevVitTrainer;
use bdia::bench::bench;
use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use bdia::metrics::fmt_bytes;
use std::time::Duration;

fn main() {
    // runs on the native backend out of the box; artifacts are optional
    for mode in [TrainMode::Vanilla, TrainMode::RevVit, TrainMode::BdiaReversible] {
        let cfg = TrainConfig {
            model: "vit_s10".into(),
            mode,
            dataset: "synth_cifar10".into(),
            steps: 1,
            eval_every: 0,
            ..TrainConfig::default()
        };
        let budget = Duration::from_secs(8);
        if mode == TrainMode::RevVit {
            let mut tr = RevVitTrainer::new(cfg.clone()).unwrap();
            let ds = dataset_for(&tr.rt, &cfg).unwrap();
            let b = ds.train_batch(0);
            let stats = tr.train_step(&b).unwrap();
            let r = bench("train_step[revvit]", 1, 12, budget, || {
                tr.train_step(&b).unwrap();
            });
            println!(
                "{}  stored acts {}",
                r.row(),
                fmt_bytes(stats.stored_activation_bytes)
            );
        } else {
            let mut tr = Trainer::new(cfg.clone()).unwrap();
            let ds = dataset_for(&tr.rt, &cfg).unwrap();
            let b = ds.train_batch(0);
            let stats = tr.train_step(&b).unwrap();
            let r = bench(
                &format!("train_step[{}]", mode.name()),
                1,
                12,
                budget,
                || {
                    tr.train_step(&b).unwrap();
                },
            );
            println!(
                "{}  stored acts {}",
                r.row(),
                fmt_bytes(stats.stored_activation_bytes)
            );
        }
    }
}
