//! Block executable latency: `block_fwd` / `block_vjp` per bundle — the L2
//! kernel cost that every training strategy shares (baseline for the
//! Table-1 step bench).

use bdia::bench::{bench, default_budget};
use bdia::model::ParamStore;
use bdia::runtime::{ArgValue, Runtime};
use bdia::tensor::{Rng, Tensor};
use std::path::Path;

fn main() {
    // native backend needs no artifacts; if artifacts/<bundle> exists the
    // manifest on disk is used instead (same ABI).
    let art = Path::new("artifacts");
    for bundle in ["vit_s10", "gpt_tiny"] {
        let rt = Runtime::load(art, bundle).expect("load");
        let dims = rt.manifest.dims.clone();
        let tokens = dims.tokens(rt.manifest.family);
        let ps = ParamStore::init(&rt.manifest, 0);
        let mut rng = Rng::new(0);
        let x = Tensor::normal(&[dims.batch, tokens, dims.d_model], 1.0, &mut rng);
        let g = Tensor::normal(&[dims.batch, tokens, dims.d_model], 1.0, &mut rng);

        let fwd = rt.exec("block_fwd").unwrap();
        let refs = ps.refs_for(&fwd.spec, 0).unwrap();
        let r = bench(&format!("{bundle}/block_fwd"), 2, 30, default_budget(), || {
            fwd.call(&refs, &[ArgValue::F32(&x)]).unwrap();
        });
        let toks = (dims.batch * tokens) as f64;
        println!("{}  ({:.0} tok/s)", r.row(), r.per_sec(toks));

        let vjp = rt.exec("block_vjp").unwrap();
        let refs = ps.refs_for(&vjp.spec, 0).unwrap();
        let r = bench(&format!("{bundle}/block_vjp"), 2, 30, default_budget(), || {
            vjp.call(&refs, &[ArgValue::F32(&x), ArgValue::F32(&g)]).unwrap();
        });
        println!("{}  ({:.0} tok/s)", r.row(), r.per_sec(toks));
    }
}
