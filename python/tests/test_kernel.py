"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal for Layer 1.  Hypothesis sweeps shapes; fixed
cases pin the paper-relevant configurations (l=9 grid, gamma = +/-0.5).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention, mha
from compile.kernels.bdia_update import (bdia_quant_combine, parity_bits,
                                         quantize, residual_quant_update)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@given(bh=st.integers(1, 4), t=st.integers(1, 33), d=st.sampled_from([4, 8, 16]),
       causal=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_mha_matches_ref(bh, t, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, bh, t, d) for _ in range(3))
    out = flash_attention(q, k, v, causal=causal)
    expect = ref.mha_ref(q, k, v, causal)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@given(tq=st.integers(1, 17), tk=st.integers(1, 29), seed=st.integers(0, 2**31 - 1))
def test_mha_cross_shapes(tq, tk, seed):
    """Cross-attention: Tq != Tk, no mask."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, 2, tq, 8)
    k = _rand(rng, 2, tk, 8)
    v = _rand(rng, 2, tk, 8)
    out = flash_attention(q, k, v, causal=False)
    expect = ref.mha_ref(q, k, v, False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_mha_causal_first_row_is_v0():
    """Causal row 0 attends only to position 0."""
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 1, 8, 4) for _ in range(3))
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[0, 0], v[0, 0], atol=1e-6)


def test_mha_tiling_invariance():
    """Different block sizes give the same result (flash recurrence exact)."""
    rng = np.random.default_rng(1)
    q, k, v = (_rand(rng, 2, 64, 8) for _ in range(3))
    o1 = flash_attention(q, k, v, causal=True, tiled=True, block_q=64, block_k=64)
    o2 = flash_attention(q, k, v, causal=True, tiled=True, block_q=16, block_k=8)
    np.testing.assert_allclose(o1, o2, atol=2e-6, rtol=2e-6)


@given(bh=st.integers(1, 3), t=st.integers(2, 40), causal=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_mha_tiled_path_matches_ref(bh, t, causal, seed):
    """The TPU-shaped tiled grid (flash running-softmax) vs the oracle."""
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, bh, t, 8) for _ in range(3))
    out = flash_attention(q, k, v, causal=causal, tiled=True,
                          block_q=16, block_k=16)
    np.testing.assert_allclose(out, ref.mha_ref(q, k, v, causal),
                               atol=2e-5, rtol=2e-5)


def test_mha_fused_and_tiled_agree():
    """Both kernel schedules compute the same function (CPU vs TPU shape)."""
    rng = np.random.default_rng(5)
    q, k, v = (_rand(rng, 4, 32, 8) for _ in range(3))
    for causal in (False, True):
        a = flash_attention(q, k, v, causal=causal, tiled=False)
        b = flash_attention(q, k, v, causal=causal, tiled=True)
        np.testing.assert_allclose(a, b, atol=2e-6, rtol=2e-6)


def test_mha_large_logits_stable():
    """Running-max softmax must not overflow with large scores."""
    rng = np.random.default_rng(2)
    q = _rand(rng, 1, 16, 8) * 100.0
    k = _rand(rng, 1, 16, 8) * 100.0
    v = _rand(rng, 1, 16, 8)
    out = flash_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref.mha_ref(q, k, v), atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 2**31 - 1), causal=st.booleans())
def test_mha_custom_vjp_matches_ref_grad(seed, causal):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, 2, 12, 8) for _ in range(3))

    def f(q, k, v):
        return jnp.sum(jnp.tanh(mha(q, k, v, causal)))

    def fr(q, k, v):
        return jnp.sum(jnp.tanh(ref.mha_ref(q, k, v, causal)))

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# quantization / BDIA update kernels (eqs. 17-22)
# ---------------------------------------------------------------------------

@given(lbits=st.sampled_from([7, 9, 11]), seed=st.integers(0, 2**31 - 1))
def test_quantize_on_grid(lbits, seed):
    rng = np.random.default_rng(seed)
    y = _rand(rng, 32) * 10
    q = quantize(y, lbits)
    scaled = np.asarray(q) * 2.0 ** lbits
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)
    assert float(jnp.max(jnp.abs(q - y))) <= 2.0 ** (-lbits) / 2 + 1e-7


def test_quantize_half_away_from_zero():
    """Tie-break must match rust quant::Fixed: round half away from zero."""
    l = 9
    step = 2.0 ** -l
    y = jnp.asarray([0.5 * step, -0.5 * step, 1.5 * step, -1.5 * step])
    q = quantize(y, l)
    np.testing.assert_allclose(q, [step, -step, 2 * step, -2 * step],
                               atol=1e-9)


@given(n=st.sampled_from([2, 6, 128]), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_residual_quant_update(n, d, seed):
    rng = np.random.default_rng(seed)
    x, h = _rand(rng, n, d), _rand(rng, n, d)
    out = residual_quant_update(x, h)
    np.testing.assert_allclose(out, ref.residual_quant_update_ref(x, h),
                               atol=1e-7)


@given(gamma=st.sampled_from([-0.5, -0.25, 0.0, 0.25, 0.5, 0.6]),
       seed=st.integers(0, 2**31 - 1))
def test_bdia_quant_combine(gamma, seed):
    rng = np.random.default_rng(seed)
    xp = ref.quantize_ref(_rand(rng, 6, 16))
    x = ref.quantize_ref(_rand(rng, 6, 16))
    h = _rand(rng, 6, 16)
    out = bdia_quant_combine(xp, x, h, gamma)
    np.testing.assert_allclose(out, ref.bdia_quant_combine_ref(xp, x, h, gamma),
                               atol=1e-7)


def test_bdia_combine_gamma0_equals_eq22():
    """gamma=0 must reduce to the standard quantized update (eq. 22)."""
    rng = np.random.default_rng(3)
    xp = ref.quantize_ref(_rand(rng, 4, 8))
    x = ref.quantize_ref(_rand(rng, 4, 8))
    h = _rand(rng, 4, 8)
    np.testing.assert_allclose(bdia_quant_combine(xp, x, h, 0.0),
                               residual_quant_update(x, h), atol=1e-7)


@given(seed=st.integers(0, 2**31 - 1))
def test_parity_bits(seed):
    rng = np.random.default_rng(seed)
    x = ref.quantize_ref(_rand(rng, 8, 8))
    s = parity_bits(x)
    np.testing.assert_allclose(s, ref.parity_bits_ref(x), atol=0)
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}


def test_parity_identity_eq23():
    """eq. 23: Q_l[gamma (x + s 2^-l)] == gamma (x + s 2^-l) exactly for
    gamma = +/-0.5 — the 1-bit side information fully absorbs the loss."""
    rng = np.random.default_rng(4)
    x = ref.quantize_ref(_rand(rng, 16, 16))
    s = parity_bits(x)
    step = 2.0 ** -9
    for gamma in (0.5, -0.5):
        y = gamma * (x + s * step)
        np.testing.assert_array_equal(np.asarray(quantize(y, 9)),
                                      np.asarray(y))
