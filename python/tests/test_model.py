"""L2 model tests: shapes, flatten determinism, BDIA inference semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref
from compile.model import ModelConfig

CFG_VIT = ModelConfig(name="t_vit", family="vit", d_model=16, n_heads=2,
                      n_blocks=3, mlp_ratio=2, batch=2, image_size=8,
                      patch=4, n_classes=4)
CFG_GPT = ModelConfig(name="t_gpt", family="gpt", d_model=16, n_heads=2,
                      n_blocks=4, mlp_ratio=2, batch=2, seq=8, vocab=11)
CFG_ED = ModelConfig(name="t_ed", family="encdec", d_model=16, n_heads=2,
                     n_blocks=2, n_enc_blocks=2, mlp_ratio=2, batch=2,
                     seq=6, seq_src=6, vocab=11)


def init_params(spec, rng):
    flat = []
    for name, shape, init in M.flatten_spec(spec):
        if init == "zeros":
            flat.append(jnp.zeros(shape, jnp.float32))
        elif init == "ones":
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            std = float(init.split(":")[1])
            flat.append(jnp.asarray(rng.normal(0, std, shape), jnp.float32))
    return M.unflatten(spec, flat)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def test_flatten_spec_deterministic():
    s1 = M.flatten_spec(M.block_spec(CFG_GPT))
    s2 = M.flatten_spec(M.block_spec(CFG_GPT))
    assert s1 == s2
    names = [n for n, _, _ in s1]
    assert names == sorted(names)  # jax sorts dict keys
    assert "attn.wq" in names and "ffn.w1" in names


def test_flatten_unflatten_roundtrip(rng):
    spec = M.block_spec(CFG_GPT, cross=True)
    p = init_params(spec, rng)
    leaves = [p[a][b] for a, b, _ in
              [(n.split(".")[0], n.split(".")[1], None)
               for n, _, _ in M.flatten_spec(spec)]]
    p2 = M.unflatten(spec, leaves)
    for grp in p:
        for k in p[grp]:
            np.testing.assert_array_equal(p[grp][k], p2[grp][k])


def test_cross_block_has_more_params():
    plain = len(M.flatten_spec(M.block_spec(CFG_ED, cross=False)))
    cross = len(M.flatten_spec(M.block_spec(CFG_ED, cross=True)))
    assert cross == plain + 10  # lnx (2) + xattn (8)


def test_patchify_shape_and_content():
    imgs = jnp.arange(2 * 3 * 8 * 8, dtype=jnp.float32).reshape(2, 3, 8, 8)
    p = M.patchify(imgs, 4)
    assert p.shape == (2, 4, 48)
    # first patch of first image, channel-last layout
    assert float(p[0, 0, 2]) == float(imgs[0, 2, 0, 0])


# ---------------------------------------------------------------------------
# block residual branch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,causal", [(CFG_VIT, False), (CFG_GPT, True)])
def test_block_h_shape(cfg, causal, rng):
    p = init_params(M.block_spec(cfg), rng)
    x = jnp.asarray(rng.normal(size=(cfg.batch, cfg.tokens, cfg.d_model)),
                    jnp.float32)
    h = M.block_h(p, x, cfg, causal)
    assert h.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(h)))


def test_block_h_is_residual_branch(rng):
    """h = f(x) + g(x + f(x)) decomposition (paper eq. 4)."""
    cfg = CFG_GPT
    p = init_params(M.block_spec(cfg), rng)
    x = jnp.asarray(rng.normal(size=(2, cfg.seq, cfg.d_model)), jnp.float32)
    xn = M.layer_norm(p["ln1"], x)
    f = M.attention(p["attn"], xn, xn, cfg.n_heads, True)
    g = M.ffn(p["ffn"], M.layer_norm(p["ln2"], x + f))
    np.testing.assert_allclose(M.block_h(p, x, cfg, True), f + g,
                               atol=1e-5, rtol=1e-5)


def test_decoder_block_uses_memory(rng):
    cfg = CFG_ED
    p = init_params(M.block_spec(cfg, cross=True), rng)
    x = jnp.asarray(rng.normal(size=(2, cfg.seq, cfg.d_model)), jnp.float32)
    m1 = jnp.asarray(rng.normal(size=(2, cfg.seq_src, cfg.d_model)), jnp.float32)
    m2 = m1 + 1.0
    h1 = M.block_h(p, x, cfg, True, mem=m1)
    h2 = M.block_h(p, x, cfg, True, mem=m2)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-6


def test_causal_no_future_leak(rng):
    """Perturbing position t must not change h at positions < t."""
    cfg = CFG_GPT
    p = init_params(M.block_spec(cfg), rng)
    x = jnp.asarray(rng.normal(size=(1, cfg.seq, cfg.d_model)), jnp.float32)
    h1 = M.block_h(p, x, cfg, causal=True)
    x2 = x.at[0, -1].add(10.0)
    h2 = M.block_h(p, x2, cfg, causal=True)
    np.testing.assert_allclose(h1[0, :-1], h2[0, :-1], atol=1e-5)


# ---------------------------------------------------------------------------
# head / loss
# ---------------------------------------------------------------------------

def test_head_loss_vit_uniform_at_zero_logits(rng):
    cfg = CFG_VIT
    p = init_params(M.head_spec(cfg), rng)
    p = {**p, "w": jnp.zeros_like(p["w"]), "b": jnp.zeros_like(p["b"])}
    x = jnp.asarray(rng.normal(size=(cfg.batch, cfg.tokens, cfg.d_model)),
                    jnp.float32)
    labels = jnp.zeros((cfg.batch,), jnp.int32)
    loss, _ = M.head_loss_apply(p, x, labels, cfg)
    np.testing.assert_allclose(loss, np.log(cfg.n_classes), rtol=1e-5)


def test_head_loss_gpt_counts_correct(rng):
    cfg = CFG_GPT
    p = init_params(M.head_spec(cfg), rng)
    x = jnp.asarray(rng.normal(size=(cfg.batch, cfg.seq, cfg.d_model)),
                    jnp.float32)
    z = M.layer_norm(p["ln_f"], x)
    logits = z @ p["w"] + p["b"]
    labels = jnp.argmax(logits, -1).astype(jnp.int32)
    _, ncorrect = M.head_loss_apply(p, x, labels, cfg)
    assert float(ncorrect) == cfg.batch * cfg.seq


# ---------------------------------------------------------------------------
# model_infer: BDIA inference semantics
# ---------------------------------------------------------------------------

def _full_params(cfg, rng):
    params = {"embed": init_params(M.embed_spec(cfg), rng),
              "blocks": [init_params(M.block_spec(cfg, cfg.family == "encdec"),
                                     rng) for _ in range(cfg.n_blocks)],
              "head": init_params(M.head_spec(cfg), rng)}
    if cfg.family == "encdec":
        params["enc_embed"] = init_params(M.enc_embed_spec(cfg), rng)
        params["enc_blocks"] = [init_params(M.block_spec(cfg, False), rng)
                                for _ in range(cfg.n_enc_blocks)]
    return params


def _ref_infer_gamma0(params, inputs, labels, cfg):
    """eq. 22 reference: plain quantized residual forward."""
    x = ref.quantize_ref(M.embed_apply(params["embed"], inputs, cfg))
    h0 = M.block_h(params["blocks"][0], x, cfg, M.is_causal(cfg))
    x = x + ref.quantize_ref(h0)
    for k in range(1, cfg.n_blocks):
        h = M.block_h(params["blocks"][k], x, cfg, M.is_causal(cfg))
        x = ref.quantize_ref(x + h)
    return M.head_loss_apply(params["head"], x, labels, cfg)


@pytest.mark.parametrize("cfg", [CFG_VIT, CFG_GPT])
def test_model_infer_gamma0_matches_eq22(cfg, rng):
    params = _full_params(cfg, rng)
    if cfg.family == "vit":
        inputs = jnp.asarray(
            rng.normal(size=(cfg.batch, 3, cfg.image_size, cfg.image_size)),
            jnp.float32)
        labels = jnp.zeros((cfg.batch,), jnp.int32)
    else:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)),
                             jnp.int32)
        labels = inputs
    loss, nc = M.model_infer(params, inputs, labels, jnp.float32(0.0), cfg)
    loss_ref, nc_ref = _ref_infer_gamma0(params, inputs, labels, cfg)
    np.testing.assert_allclose(loss, loss_ref, atol=1e-5, rtol=1e-5)
    assert float(nc) == float(nc_ref)


def test_model_infer_gamma_sensitivity(rng):
    """gamma != 0 changes the output (different ODE solver, Fig. 1)."""
    cfg = CFG_GPT
    params = _full_params(cfg, rng)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)),
                         jnp.int32)
    l0, _ = M.model_infer(params, tokens, tokens, jnp.float32(0.0), cfg)
    l5, _ = M.model_infer(params, tokens, tokens, jnp.float32(0.5), cfg)
    assert abs(float(l0) - float(l5)) > 1e-7


def test_model_infer_encdec(rng):
    cfg = CFG_ED
    params = _full_params(cfg, rng)
    src = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_src)),
                      jnp.int32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)),
                      jnp.int32)
    loss, nc = M.model_infer(params, (src, tgt), tgt, jnp.float32(0.0), cfg)
    assert np.isfinite(float(loss))
    assert 0 <= float(nc) <= cfg.batch * cfg.seq
