"""AOT bundle integrity: manifests agree with HLO files and model specs.

These tests validate the Python->Rust ABI without needing the Rust side:
input counts, output counts, shape bookkeeping, incremental-export hashing.
"""

import json
import pathlib

import pytest

from compile import aot, model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

SMOKE = ["smoke_vit", "smoke_gpt", "smoke_encdec"]


def _manifest(name):
    p = ART / name / "manifest.json"
    if not p.exists():
        pytest.skip(f"artifacts for {name} not built (run `make artifacts`)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("name", SMOKE)
def test_manifest_groups_match_specs(name):
    mf = _manifest(name)
    cfg = aot.CONFIGS[name]
    cross = cfg.family == "encdec"
    expect = {
        "embed": M.embed_spec(cfg),
        "block": M.block_spec(cfg, cross=cross),
        "head": M.head_spec(cfg),
    }
    if cross:
        expect["enc_embed"] = M.enc_embed_spec(cfg)
        expect["enc_block"] = M.block_spec(cfg, cross=False)
    assert set(mf["param_groups"]) == set(expect)
    for grp, spec in expect.items():
        flat = M.flatten_spec(spec)
        got = mf["param_groups"][grp]
        assert [g["name"] for g in got] == [n for n, _, _ in flat]
        assert [tuple(g["shape"]) for g in got] == [s for _, s, _ in flat]
        assert [g["init"] for g in got] == [i for _, _, i in flat]


@pytest.mark.parametrize("name", SMOKE)
def test_hlo_files_exist_and_parse_header(name):
    mf = _manifest(name)
    for ename, e in mf["executables"].items():
        path = ART / name / e["file"]
        assert path.exists(), ename
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{ename} not HLO text"


@pytest.mark.parametrize("name", SMOKE)
def test_hlo_param_count_matches_manifest(name):
    """ENTRY computation parameter count == param leaves + data inputs."""
    mf = _manifest(name)
    for ename, e in mf["executables"].items():
        n_params = sum(len(mf["param_groups"][g]) * c
                       for g, c in e["param_layout"])
        expect = n_params + len(e["data_inputs"])
        text = (ART / name / e["file"]).read_text()
        # count parameter declarations inside the ENTRY computation only
        # (nested fusion computations declare their own parameters)
        lines = text.splitlines()
        start = next(i for i, ln in enumerate(lines) if ln.startswith("ENTRY"))
        got = 0
        for ln in lines[start + 1:]:
            if ln.startswith("}"):
                break
            if "= parameter(" in ln or " parameter(" in ln:
                got += 1
        assert got == expect, f"{name}/{ename}: {got} != {expect}"


@pytest.mark.parametrize("name", SMOKE)
def test_block_vjp_output_layout(name):
    """block_vjp returns (h, dx[, dmem], dparams...) per DESIGN.md §8."""
    mf = _manifest(name)
    cfg = aot.CONFIGS[name]
    e = mf["executables"]["block_vjp"]
    nb = len(mf["param_groups"]["block"])
    extra = 1 if cfg.family == "encdec" else 0  # dmem
    assert len(e["outputs"]) == 2 + extra + nb
    x_shape = [cfg.batch, cfg.tokens, cfg.d_model]
    assert e["outputs"][0]["shape"] == x_shape  # h
    assert e["outputs"][1]["shape"] == x_shape  # dx


@pytest.mark.parametrize("name", SMOKE)
def test_model_infer_scalar_outputs(name):
    mf = _manifest(name)
    e = mf["executables"]["model_infer"]
    assert [o["shape"] for o in e["outputs"]] == [[], []]  # (loss, ncorrect)
    assert e["data_inputs"][-1]["name"] == "gamma"


def test_source_hash_stability():
    cfg = aot.CONFIGS["smoke_gpt"]
    assert aot.compute_source_hash(cfg) == aot.compute_source_hash(cfg)
    assert aot.compute_source_hash(cfg) != aot.compute_source_hash(
        aot.CONFIGS["smoke_vit"])


def test_up_to_date_detection(tmp_path):
    cfg = aot.CONFIGS["smoke_gpt"]
    h = aot.compute_source_hash(cfg)
    assert not aot.bundle_up_to_date(cfg, tmp_path, h)
    if (ART / "smoke_gpt" / "manifest.json").exists():
        assert aot.bundle_up_to_date(cfg, ART, h)
        assert not aot.bundle_up_to_date(cfg, ART, "deadbeef")
