"""L2: the paper's models in JAX — ViT, GPT-style LM, encoder-decoder.

Everything here is *build-time only*.  ``aot.py`` lowers per-component
functions (embed / block / head+loss, forward and VJP) to HLO text that the
Rust coordinator executes at train time.  The unit of compilation is the
transformer-block **residual branch**

    h_k(x) = f_k(x) + g_k(x + f_k(x))                          (paper eq. 4)

because the BDIA combine (eq. 10/21) — with its per-sample gamma randomness
and exact fixed-point arithmetic — lives in the Rust coordinator, not in HLO
(DESIGN.md §2).

Parameters are nested dicts; ``flatten_spec`` fixes a deterministic leaf
order (jax's sorted-dict-key traversal) that the manifest records and the
Rust ``model::ParamStore`` mirrors.

The attention hot loop is the Pallas kernel ``kernels.attention.mha`` (L1);
the quantized inference update is ``kernels.bdia_update`` (eqs. 17/21/22).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import mha
from compile.kernels.bdia_update import bdia_quant_combine, residual_quant_update


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape/hyperparameter bundle; one AOT artifact set per config."""
    name: str
    family: str            # "vit" | "gpt" | "encdec"
    d_model: int
    n_heads: int
    n_blocks: int          # K (decoder depth for encdec)
    mlp_ratio: int = 4
    batch: int = 32
    lbits: int = 9         # fixed-point grid 2^-l (paper: l = 9)
    # vit
    image_size: int = 32
    patch: int = 4
    channels: int = 3
    n_classes: int = 10
    # lm / encdec
    seq: int = 64          # decoder/LM sequence length
    vocab: int = 96
    # encdec
    n_enc_blocks: int = 0
    seq_src: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def tokens(self) -> int:
        """Sequence length seen by the (decoder) blocks."""
        if self.family == "vit":
            return (self.image_size // self.patch) ** 2 + 1  # + cls token
        return self.seq

    def dims_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Parameter initialisation specs
# ---------------------------------------------------------------------------
# Init happens in Rust (seeds owned by the coordinator); Python only records
# the distribution of every leaf in the manifest: "normal:<std>", "zeros",
# "ones".

INIT_NORMAL = "normal:0.02"
INIT_ZEROS = "zeros"
INIT_ONES = "ones"


def _ln_spec(d: int):
    return {"scale": ((d,), INIT_ONES), "bias": ((d,), INIT_ZEROS)}


def _attn_spec(d: int):
    return {
        "wq": ((d, d), INIT_NORMAL), "bq": ((d,), INIT_ZEROS),
        "wk": ((d, d), INIT_NORMAL), "bk": ((d,), INIT_ZEROS),
        "wv": ((d, d), INIT_NORMAL), "bv": ((d,), INIT_ZEROS),
        "wo": ((d, d), INIT_NORMAL), "bo": ((d,), INIT_ZEROS),
    }


def _ffn_spec(d: int, ratio: int):
    return {
        "w1": ((d, d * ratio), INIT_NORMAL), "b1": ((d * ratio,), INIT_ZEROS),
        "w2": ((d * ratio, d), INIT_NORMAL), "b2": ((d,), INIT_ZEROS),
    }


def block_spec(cfg: ModelConfig, cross: bool = False):
    spec = {
        "ln1": _ln_spec(cfg.d_model),
        "attn": _attn_spec(cfg.d_model),
        "ln2": _ln_spec(cfg.d_model),
        "ffn": _ffn_spec(cfg.d_model, cfg.mlp_ratio),
    }
    if cross:
        spec["lnx"] = _ln_spec(cfg.d_model)
        spec["xattn"] = _attn_spec(cfg.d_model)
    return spec


def embed_spec(cfg: ModelConfig):
    d = cfg.d_model
    if cfg.family == "vit":
        pdim = cfg.patch * cfg.patch * cfg.channels
        return {
            "proj_w": ((pdim, d), INIT_NORMAL), "proj_b": ((d,), INIT_ZEROS),
            "cls": ((1, 1, d), INIT_NORMAL),
            "pos": ((cfg.tokens, d), INIT_NORMAL),
        }
    if cfg.family in ("gpt", "encdec"):
        return {"wte": ((cfg.vocab, d), INIT_NORMAL),
                "wpe": ((cfg.seq, d), INIT_NORMAL)}
    raise ValueError(cfg.family)


def enc_embed_spec(cfg: ModelConfig):
    return {"wte": ((cfg.vocab, cfg.d_model), INIT_NORMAL),
            "wpe": ((cfg.seq_src, cfg.d_model), INIT_NORMAL)}


def head_spec(cfg: ModelConfig):
    d = cfg.d_model
    out = cfg.n_classes if cfg.family == "vit" else cfg.vocab
    return {"ln_f": _ln_spec(d),
            "w": ((d, out), INIT_NORMAL), "b": ((out,), INIT_ZEROS)}


def _is_leaf(x):
    return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
            and isinstance(x[1], str))


def flatten_spec(spec) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Deterministic (name, shape, init) list in jax flatten order."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(spec, is_leaf=_is_leaf)
    out = []
    for path, (shape, init) in leaves:
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        out.append((name, shape, init))
    return out


def spec_treedef(spec):
    _, treedef = jax.tree_util.tree_flatten_with_path(spec, is_leaf=_is_leaf)
    return treedef


def unflatten(spec, leaves):
    return jax.tree_util.tree_unflatten(spec_treedef(spec), list(leaves))


# ---------------------------------------------------------------------------
# Components
# ---------------------------------------------------------------------------

def layer_norm(p, x, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]


def attention(p, x, kv, n_heads: int, causal: bool):
    """Multi-head attention; inner loop is the L1 Pallas kernel."""
    b, tq, d = x.shape
    tk = kv.shape[1]
    dh = d // n_heads
    q = x @ p["wq"] + p["bq"]
    k = kv @ p["wk"] + p["bk"]
    v = kv @ p["wv"] + p["bv"]

    def fold(t, tlen):
        return (t.reshape(b, tlen, n_heads, dh).transpose(0, 2, 1, 3)
                .reshape(b * n_heads, tlen, dh))

    o = mha(fold(q, tq), fold(k, tk), fold(v, tk), causal)
    o = (o.reshape(b, n_heads, tq, dh).transpose(0, 2, 1, 3)
         .reshape(b, tq, d))
    return o @ p["wo"] + p["bo"]


def ffn(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def block_h(p, x, cfg: ModelConfig, causal: bool, mem=None):
    """The residual branch h_k(x) = f_k(x) + g_k(x + f_k(x))  (eq. 4).

    Decoder blocks (mem != None) compose three sub-residuals (self-attn,
    cross-attn, FFN); the coordinator only ever sees the total h.
    """
    xn = layer_norm(p["ln1"], x)
    a = attention(p["attn"], xn, xn, cfg.n_heads, causal)
    u = x + a
    if mem is not None:
        c = attention(p["xattn"], layer_norm(p["lnx"], u), mem,
                      cfg.n_heads, causal=False)
        u = u + c
    f = ffn(p["ffn"], layer_norm(p["ln2"], u))
    return (u + f) - x


# ---------------------------------------------------------------------------
# Family-specific embed / head+loss
# ---------------------------------------------------------------------------

def patchify(images, patch: int):
    """(B, C, H, W) -> (B, H/p * W/p, p*p*C)."""
    b, c, h, w = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, c, gh, patch, gw, patch)
    x = x.transpose(0, 2, 4, 3, 5, 1)  # b, gh, gw, p, p, c
    return x.reshape(b, gh * gw, patch * patch * c)


def embed_apply(p, inputs, cfg: ModelConfig):
    if cfg.family == "vit":
        x = patchify(inputs, cfg.patch) @ p["proj_w"] + p["proj_b"]
        cls = jnp.broadcast_to(p["cls"], (x.shape[0], 1, cfg.d_model))
        x = jnp.concatenate([cls, x], axis=1)
        return x + p["pos"][None]
    # token embedding (gpt / encdec decoder / encdec encoder)
    t = inputs.shape[1]
    return p["wte"][inputs] + p["wpe"][:t][None]


def head_loss_apply(p, x, labels, cfg: ModelConfig):
    """Returns (mean CE loss, #correct) — both f32 scalars."""
    z = layer_norm(p["ln_f"], x)
    if cfg.family == "vit":
        z = z[:, 0]  # cls token
        logits = z @ p["w"] + p["b"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        ncorrect = jnp.sum(jnp.argmax(logits, -1) == labels).astype(jnp.float32)
        return loss, ncorrect
    logits = z @ p["w"] + p["b"]  # (B, T, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    loss = -jnp.mean(picked)
    ncorrect = jnp.sum(jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return loss, ncorrect


def is_causal(cfg: ModelConfig) -> bool:
    return cfg.family in ("gpt", "encdec")


# ---------------------------------------------------------------------------
# Full-model quantized inference (the AOT `model_infer` executable)
# ---------------------------------------------------------------------------
# eqs. 18, 19, 21/22 with a *constant* gamma supplied at runtime: gamma = 0
# is standard inference (E[gamma]; eq. 22); other values realise the Fig.-1
# ODE-solver sweep.  Uses the fused L1 bdia_update kernels.

def _quantize3(y, cfg: ModelConfig):
    b, t, d = y.shape
    return residual_quant_update(
        y.reshape(b * t, d), jnp.zeros((b * t, d), jnp.float32),
        lbits=cfg.lbits).reshape(b, t, d)


def _stack_infer(blocks_p, x, gamma, cfg: ModelConfig, causal: bool, mem=None):
    b, t, d = x.shape
    x0 = _quantize3(x, cfg)  # eq. 18
    h0 = block_h(blocks_p[0], x0, cfg, causal, mem)
    x1 = x0 + _quantize3(h0, cfg)  # eq. 19
    xprev, xcur = x0, x1
    for k in range(1, len(blocks_p)):
        h = block_h(blocks_p[k], xcur, cfg, causal, mem)
        nxt = bdia_quant_combine(
            xprev.reshape(b * t, d), xcur.reshape(b * t, d),
            h.reshape(b * t, d), gamma, lbits=cfg.lbits).reshape(b, t, d)
        xprev, xcur = xcur, nxt
    return xcur


def model_infer(params, inputs, labels, gamma, cfg: ModelConfig):
    """params: dict with keys embed/blocks/head (+enc_embed/enc_blocks).

    blocks are lists of per-block param dicts.  Returns (loss, ncorrect).
    """
    if cfg.family == "encdec":
        src, tgt = inputs
        xe = embed_apply(params["enc_embed"], src, cfg)
        mem = _stack_infer(params["enc_blocks"], xe, gamma, cfg, causal=False)
        xd = embed_apply(params["embed"], tgt, cfg)
        xk = _stack_infer(params["blocks"], xd, gamma, cfg, causal=True,
                          mem=mem)
    else:
        x = embed_apply(params["embed"], inputs, cfg)
        xk = _stack_infer(params["blocks"], x, gamma, cfg,
                          causal=is_causal(cfg))
    return head_loss_apply(params["head"], xk, labels, cfg)
