"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests/test_kernel.py``) asserts allclose between kernel and oracle
across shape/dtype sweeps (hypothesis).  This is the CORE correctness signal
for Layer 1.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, causal: bool = False):
    """softmax(Q K^T / sqrt(d)) V over folded heads: (BH, T, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / math.sqrt(d)
    if causal:
        tq, tk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((tq, tk), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def quantize_ref(y, lbits: int = 9):
    """Q_l[y] (eq. 17), round-half-away-from-zero on the 2^-l grid."""
    scale = 2.0 ** lbits
    scaled = y * scale
    r = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    return r / scale


def residual_quant_update_ref(x, h, lbits: int = 9):
    """x_{k+1} = Q_l[x + h] (eq. 22)."""
    return quantize_ref(x + h, lbits)


def bdia_quant_combine_ref(x_prev, x, h, gamma, lbits: int = 9):
    """Constant-gamma quantized BDIA combine (inference form of eq. 21)."""
    return (quantize_ref(gamma * x_prev, lbits)
            + quantize_ref((1.0 - gamma) * x + (1.0 + gamma) * h, lbits))


def parity_bits_ref(x, lbits: int = 9):
    """s[m] = |x[m]/2^-l| mod 2 (eq. 20) for on-grid x."""
    scale = 2.0 ** lbits
    n = jnp.sign(x * scale) * jnp.floor(jnp.abs(x * scale) + 0.5)
    return jnp.abs(jnp.mod(n, 2.0))
