"""L1 Pallas kernel: fused quantized BDIA / residual update (paper eqs. 17-22).

At inference the BDIA-transformer collapses (E[gamma]=0) to the standard
update with activation quantization only:

    x_{k+1} = Q_l[x_k + h_k(x_k)]                                  (eq. 22)

and, for the Fig.-1 gamma-sweep inference path, the full BDIA combine with a
*constant* gamma (eq. 10, quantized per eq. 21 with s treated on-grid):

    x_{k+1} = Q_l[gamma * x_{k-1}] + Q_l[(1-gamma) x_k + (1+gamma) h_k]

Both are single-pass elementwise kernels: quantize + combine fused so the
activation makes one HBM round-trip instead of three.  ``Q_l[y] =
round(y * 2^l) * 2^-l`` (eq. 17).  The kernels run under ``interpret=True``
(CPU lowering); on TPU they are pure VPU ops.

The exact-reversibility *training* combine (eq. 21, with the parity side
information s_{k-1}) lives in the Rust coordinator in i64 grid units — that is
the paper's system contribution and must be bit-exact; see
``rust/src/quant/``.  The kernels here are the inference hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quantize(y, lbits: int):
    """Q_l[y] = round(y / 2^-l) * 2^-l  (eq. 17), round-half-away-from-zero.

    jnp.round is banker's rounding; the paper's fixed-point grid only needs a
    *deterministic* rule, and the Rust coordinator matches this exact choice
    (see rust/src/quant/fixed.rs).
    """
    scale = jnp.float32(2.0 ** lbits)
    scaled = y * scale
    r = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    return r / scale


def _resq_kernel(x_ref, h_ref, o_ref, *, lbits: int):
    o_ref[...] = quantize(x_ref[...] + h_ref[...], lbits)


def residual_quant_update(x, h, *, lbits: int = 9, block_rows: int = 0,
                          interpret: bool = True):
    """x_{k+1} = Q_l[x + h]  (eq. 22), fused elementwise Pallas kernel.

    x, h: (N, D) float32 (callers flatten batch/seq dims).
    """
    n, d = x.shape
    br = min(block_rows, n) if block_rows else n
    while n % br != 0:
        br -= 1
    kernel = functools.partial(_resq_kernel, lbits=lbits)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(x, h)


def _bdia_kernel(xprev_ref, x_ref, h_ref, gamma_ref, o_ref, *, lbits: int):
    g = gamma_ref[0]
    xprev = xprev_ref[...]
    x = x_ref[...]
    h = h_ref[...]
    term1 = quantize(g * xprev, lbits)
    term2 = quantize((1.0 - g) * x + (1.0 + g) * h, lbits)
    o_ref[...] = term1 + term2


def bdia_quant_combine(x_prev, x, h, gamma, *, lbits: int = 9,
                       block_rows: int = 0, interpret: bool = True):
    """Constant-gamma quantized BDIA combine (inference / Fig.-1 sweep).

    x_prev, x, h: (N, D) float32; gamma: scalar float32 (traced — the AOT
    executable takes it as a runtime input so one artifact serves the whole
    gamma sweep).
    """
    n, d = x.shape
    br = min(block_rows, n) if block_rows else n
    while n % br != 0:
        br -= 1
    gamma = jnp.asarray(gamma, jnp.float32).reshape((1,))
    kernel = functools.partial(_bdia_kernel, lbits=lbits)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(x_prev, x, h, gamma)


def _parity_kernel(x_ref, s_ref, *, lbits: int):
    scale = jnp.float32(2.0 ** lbits)
    scaled = x_ref[...] * scale
    n = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)  # on-grid => exact
    s_ref[...] = jnp.abs(jnp.mod(n, 2.0))


def parity_bits(x, *, lbits: int = 9, block_rows: int = 0,
                interpret: bool = True):
    """s[m] = |x[m]/2^-l| mod 2  (eq. 20): the 1-bit side information.

    Returned as float32 0/1 (HLO-friendly); the Rust coordinator packs the
    production side-info bitsets itself — this kernel exists for kernel-level
    validation and the inference-path artifacts.
    """
    n, d = x.shape
    br = min(block_rows, n) if block_rows else n
    while n % br != 0:
        br -= 1
    kernel = functools.partial(_parity_kernel, lbits=lbits)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(x)
