"""L1 Pallas kernel: fused multi-head attention (flash-style).

The paper's compute hot-spot is the transformer block; its inner hot loop is
``softmax(Q K^T / sqrt(d)) V``.  This module implements it as a Pallas kernel
tiled for VMEM residency:

  * grid = (BH, num_q_tiles): one program per (batch*head, q-tile),
  * the q-tile (``block_q x d_head``) stays resident in VMEM,
  * K/V are scanned in ``block_k``-sized tiles with a running
    (max, denominator, accumulator) softmax — the flash-attention recurrence —
    so the working set is O(block_q * d_head + block_k * d_head), never O(T^2).

On a real TPU the two contractions map onto the MXU (bf16); in this repo the
kernel runs under ``interpret=True`` so it lowers to plain HLO that the CPU
PJRT client can execute (see DESIGN.md §3 Hardware adaptation).

Autodiff: ``pallas_call`` has no automatic VJP, so ``mha`` carries a
``jax.custom_vjp`` whose backward pass is the closed-form attention gradient
(pure jnp, fused by XLA).  The backward runs inside the AOT ``block_vjp``
executable, never in Python at train time.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float, causal: bool,
                  block_k: int, seq_k: int):
    """One (batch*head, q-tile) program of the flash-attention forward."""
    q = q_ref[0, ...]  # (block_q, d)
    block_q, d = q.shape
    q_tile = pl.program_id(1)
    q_off = q_tile * block_q

    num_kv = pl.cdiv(seq_k, block_k)

    def body(kv_i, carry):
        o_acc, m_i, l_i = carry
        k = pl.load(k_ref, (0, pl.dslice(kv_i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kv_i * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T) * sm_scale  # (block_q, block_k)
        if causal:
            # global row/col indices of this tile pair
            rows = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kv_i * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        o_new = o_acc * alpha[:, None] + jnp.dot(p, v)
        return o_new, m_new, l_new

    o0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)

    if causal:
        # tiles strictly above the diagonal contribute nothing; skip them.
        num_kv_here = jnp.minimum(
            num_kv, pl.cdiv(q_off + block_q, block_k)).astype(jnp.int32)
    else:
        num_kv_here = num_kv

    o, m, l = jax.lax.fori_loop(0, num_kv_here, body, (o0, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (cannot happen causally)
    o_ref[0, ...] = (o / l[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (keeps tiles aligned, no padding)."""
    b = min(pref, n)
    while n % b != 0:
        b -= 1
    return b


def _fused_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float, causal: bool):
    """Single-program variant: the whole (BH, T, d) workload in one kernel.

    Under interpret=True the tiled grid lowers to a `fori_loop` of tiny
    dynamic-slice matmuls, which the CPU backend executes ~35x slower than
    one batched contraction (measured; EXPERIMENTS.md §Perf).  This variant
    keeps the kernel abstraction but lets XLA-CPU see fused batched einsums.
    On a real TPU the tiled variant is the right choice (VMEM residency);
    the AOT exporter picks per target — see DESIGN.md §Hardware-Adaptation.
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    s = jnp.einsum("bqd,bkd->bqk", q, k) * sm_scale
    if causal:
        tq, tk = q.shape[1], k.shape[1]
        rows = jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        s = jnp.where((rows >= cols)[None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bqk,bkd->bqd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = False,
                    block_q: int = 64, block_k: int = 64,
                    interpret: bool = True, tiled: bool = False):
    """Fused attention over folded heads.

    Args:
      q: (BH, Tq, d) float32.
      k, v: (BH, Tk, d) float32.
      causal: apply the autoregressive mask (requires Tq == Tk).
      tiled: use the per-(head, q-tile) grid with the flash running-softmax
        recurrence — the TPU/VMEM-shaped schedule.  False (default) runs the
        single-program fused variant, which is what the CPU-PJRT AOT bundles
        ship (see `_fused_kernel` for why).
    Returns:
      (BH, Tq, d) float32.
    """
    bh, tq, d = q.shape
    _, tk, _ = k.shape
    if causal and tq != tk:
        raise ValueError("causal attention requires Tq == Tk")
    sm_scale = 1.0 / math.sqrt(d)
    if not tiled:
        kernel = functools.partial(_fused_kernel, sm_scale=sm_scale, causal=causal)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((bh, tq, d), jnp.float32),
            interpret=interpret,
        )(q, k, v)
    bq = _pick_block(tq, block_q)
    bk = _pick_block(tk, block_k)
    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale, causal=causal,
                               block_k=bk, seq_k=tk)
    grid = (bh, tq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, tk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# custom VJP: forward = pallas kernel, backward = closed-form attention grad.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def mha(q, k, v, causal: bool = False):
    """Differentiable fused attention. Shapes as ``flash_attention``."""
    return flash_attention(q, k, v, causal=causal)


def _mha_fwd(q, k, v, causal):
    o = flash_attention(q, k, v, causal=causal)
    return o, (q, k, v)


def _mha_bwd(causal, res, do):
    q, k, v = res
    d = q.shape[-1]
    sm_scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q, k) * sm_scale
    if causal:
        tq = q.shape[1]
        mask = jnp.tril(jnp.ones((tq, tq), bool))
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    # softmax jacobian: dS = P * (dP - rowsum(dP * P))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = ds * sm_scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, k)
    dk = jnp.einsum("bqk,bqd->bkd", ds, q)
    return dq, dk, dv


mha.defvjp(_mha_fwd, _mha_bwd)
