"""AOT export: lower L2 model components to HLO text bundles for Rust.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One bundle per ``ModelConfig``: ``artifacts/<name>/*.hlo.txt`` plus
``manifest.json`` describing, for every executable, the parameter-group
layout and data inputs/outputs, and for every parameter group the leaf
(name, shape, init) list in flatten order.  The Rust ``model`` module mirrors
this layout exactly — it is the ABI between the layers.

Conventions (DESIGN.md §8):
  * executable inputs = [param leaves in manifest order] ++ [data inputs]
  * every executable returns a tuple (lowered with return_tuple=True)
  * ``block_vjp`` returns (h, dx, dparams...) — the primal h is reused by the
    coordinator for the eq.-24 reconstruction, saving a forward call.

Incremental: a bundle is skipped when its manifest's ``source_hash`` matches
the current config + compile-package sources (``make artifacts`` is a no-op
on an unchanged tree).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.model import ModelConfig


# ---------------------------------------------------------------------------
# Config registry — every config used by experiments/examples/tests.
# ---------------------------------------------------------------------------
# Scaled for the single-CPU PJRT testbed (DESIGN.md §5 records the
# substitutions: paper depths kept, widths reduced).

CONFIGS: Dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# Paper §5.1: ViT with K=6 blocks on CIFAR10/100 (batch 128 in the paper;
# 64 here), Fig. 1 / Fig. 3 / Table 1 / Table 2.
_reg(ModelConfig(name="vit_s10", family="vit", d_model=64, n_heads=4,
                 n_blocks=6, mlp_ratio=2, batch=64, n_classes=10))
_reg(ModelConfig(name="vit_s100", family="vit", d_model=64, n_heads=4,
                 n_blocks=6, mlp_ratio=2, batch=64, n_classes=100))
# Paper §5.3: (nano)GPT2 with 12 blocks, tiny-corpus overfitting (Fig. 5),
# and the Fig.-2 float-reversibility error-accumulation demo.
_reg(ModelConfig(name="gpt_tiny", family="gpt", d_model=64, n_heads=4,
                 n_blocks=12, mlp_ratio=2, batch=16, seq=64, vocab=96))
# Paper §5.2: en->fr translation, 6+6 encoder/decoder blocks (Fig. 4).
_reg(ModelConfig(name="encdec_mt", family="encdec", d_model=64, n_heads=4,
                 n_blocks=6, n_enc_blocks=6, mlp_ratio=2, batch=32,
                 seq=24, seq_src=24, vocab=64))
# End-to-end driver: largest feasible LM on this testbed (examples/e2e_train).
_reg(ModelConfig(name="gpt_e2e", family="gpt", d_model=256, n_heads=8,
                 n_blocks=8, mlp_ratio=4, batch=8, seq=128, vocab=96))
# Tiny smoke configs for cargo integration tests (fast to build & run).
_reg(ModelConfig(name="smoke_vit", family="vit", d_model=16, n_heads=2,
                 n_blocks=3, mlp_ratio=2, batch=2, image_size=8, patch=4,
                 n_classes=4))
_reg(ModelConfig(name="smoke_gpt", family="gpt", d_model=16, n_heads=2,
                 n_blocks=4, mlp_ratio=2, batch=2, seq=8, vocab=11))
_reg(ModelConfig(name="smoke_encdec", family="encdec", d_model=16, n_heads=2,
                 n_blocks=2, n_enc_blocks=2, mlp_ratio=2, batch=2, seq=6,
                 seq_src=6, vocab=11))


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _leaf_sds(spec):
    return [_sds(shape) for _, shape, _ in M.flatten_spec(spec)]


def _dtype_str(d) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


class BundleWriter:
    """Collects executables + manifest for one config."""

    def __init__(self, cfg: ModelConfig, out_dir: pathlib.Path):
        self.cfg = cfg
        self.dir = out_dir / cfg.name
        self.dir.mkdir(parents=True, exist_ok=True)
        self.manifest = {
            "name": cfg.name,
            "family": cfg.family,
            "dims": cfg.dims_dict(),
            "param_groups": {},
            "executables": {},
        }

    def add_group(self, group: str, spec) -> None:
        self.manifest["param_groups"][group] = [
            {"name": n, "shape": list(s), "init": i}
            for n, s, i in M.flatten_spec(spec)]

    def export(self, exec_name: str, fn, param_layout: List[List],
               data_inputs: List, example_args: Sequence) -> None:
        """param_layout: [[group, count], ...]; data_inputs: [(name, sds)]."""
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{exec_name}.hlo.txt"
        (self.dir / fname).write_text(text)
        outs = jax.eval_shape(fn, *example_args)
        outs_flat = jax.tree_util.tree_leaves(outs)
        self.manifest["executables"][exec_name] = {
            "file": fname,
            "param_layout": [[g, int(c)] for g, c in param_layout],
            "data_inputs": [{"name": n, "dtype": _dtype_str(s.dtype),
                             "shape": list(s.shape)} for n, s in data_inputs],
            "outputs": [{"dtype": _dtype_str(o.dtype),
                         "shape": list(o.shape)} for o in outs_flat],
        }
        print(f"  [{self.cfg.name}] {exec_name}: "
              f"{len(text) // 1024}KB, {len(outs_flat)} outputs")

    def finish(self, source_hash: str) -> None:
        self.manifest["source_hash"] = source_hash
        (self.dir / "manifest.json").write_text(
            json.dumps(self.manifest, indent=1))


# ---------------------------------------------------------------------------
# Per-family export
# ---------------------------------------------------------------------------

def _inputs_sds(cfg: ModelConfig):
    if cfg.family == "vit":
        return _sds((cfg.batch, cfg.channels, cfg.image_size, cfg.image_size))
    return _sds((cfg.batch, cfg.seq), jnp.int32)


def _labels_sds(cfg: ModelConfig):
    if cfg.family == "vit":
        return _sds((cfg.batch,), jnp.int32)
    return _sds((cfg.batch, cfg.seq), jnp.int32)


def _x_sds(cfg: ModelConfig):
    return _sds((cfg.batch, cfg.tokens, cfg.d_model))


def export_bundle(cfg: ModelConfig, out_dir: pathlib.Path,
                  source_hash: str) -> None:
    w = BundleWriter(cfg, out_dir)
    causal = M.is_causal(cfg)
    cross = cfg.family == "encdec"

    espec = M.embed_spec(cfg)
    bspec = M.block_spec(cfg, cross=cross)
    hspec = M.head_spec(cfg)
    w.add_group("embed", espec)
    w.add_group("block", bspec)
    w.add_group("head", hspec)

    ne = len(M.flatten_spec(espec))
    nb = len(M.flatten_spec(bspec))
    nh = len(M.flatten_spec(hspec))

    x_s = _x_sds(cfg)
    in_s = _inputs_sds(cfg)
    lab_s = _labels_sds(cfg)
    mem_s = None
    if cross:
        eespec = M.enc_embed_spec(cfg)
        ebspec = M.block_spec(cfg, cross=False)
        w.add_group("enc_embed", eespec)
        w.add_group("enc_block", ebspec)
        nee = len(M.flatten_spec(eespec))
        neb = len(M.flatten_spec(ebspec))
        mem_s = _sds((cfg.batch, cfg.seq_src, cfg.d_model))
        src_s = _sds((cfg.batch, cfg.seq_src), jnp.int32)

    # ---- embed ----
    def embed_fwd(*args):
        p = M.unflatten(espec, args[:ne])
        return (M.embed_apply(p, args[ne], cfg),)

    w.export("embed_fwd", embed_fwd, [["embed", 1]],
             [("inputs", in_s)], [*_leaf_sds(espec), in_s])

    def embed_vjp(*args):
        leaves, inputs, g = args[:ne], args[ne], args[ne + 1]
        def f(lv):
            return M.embed_apply(M.unflatten(espec, lv), inputs, cfg)
        _, pull = jax.vjp(f, leaves)
        (dl,) = pull(g)
        return tuple(dl)

    w.export("embed_vjp", embed_vjp, [["embed", 1]],
             [("inputs", in_s), ("g", x_s)],
             [*_leaf_sds(espec), in_s, x_s])

    # ---- block (decoder/self block) ----
    def block_fwd(*args):
        p = M.unflatten(bspec, args[:nb])
        if cross:
            return (M.block_h(p, args[nb], cfg, causal, mem=args[nb + 1]),)
        return (M.block_h(p, args[nb], cfg, causal),)

    bf_data = [("x", x_s)] + ([("mem", mem_s)] if cross else [])
    w.export("block_fwd", block_fwd, [["block", 1]], bf_data,
             [*_leaf_sds(bspec)] + [s for _, s in bf_data])

    def block_vjp(*args):
        leaves = args[:nb]
        if cross:
            x, mem, g = args[nb], args[nb + 1], args[nb + 2]
            def f(lv, xx, mm):
                return M.block_h(M.unflatten(bspec, lv), xx, cfg, causal, mm)
            h, pull = jax.vjp(f, leaves, x, mem)
            dl, dx, dmem = pull(g)
            return (h, dx, dmem, *dl)
        x, g = args[nb], args[nb + 1]
        def f(lv, xx):
            return M.block_h(M.unflatten(bspec, lv), xx, cfg, causal)
        h, pull = jax.vjp(f, leaves, x)
        dl, dx = pull(g)
        return (h, dx, *dl)

    bv_data = bf_data + [("g", x_s)]
    w.export("block_vjp", block_vjp, [["block", 1]], bv_data,
             [*_leaf_sds(bspec)] + [s for _, s in bv_data])

    # ---- RevViT [19] sub-branch executables (vit/gpt families) ----
    # Two-stream reversible baseline: F = attn(ln1(.)), G = ffn(ln2(.)).
    # Same "block" param group (keep_unused pads the untouched leaves with
    # zero grads), so the Rust side reuses the group layout unchanged.
    if not cross:
        def attn_fwd(*args):
            p = M.unflatten(bspec, args[:nb])
            xn = M.layer_norm(p["ln1"], args[nb])
            return (M.attention(p["attn"], xn, xn, cfg.n_heads, causal),)

        w.export("attn_fwd", attn_fwd, [["block", 1]], [("x", x_s)],
                 [*_leaf_sds(bspec), x_s])

        def attn_vjp(*args):
            leaves, x, g = args[:nb], args[nb], args[nb + 1]
            def f(lv, xx):
                p = M.unflatten(bspec, lv)
                xn = M.layer_norm(p["ln1"], xx)
                return M.attention(p["attn"], xn, xn, cfg.n_heads, causal)
            out, pull = jax.vjp(f, leaves, x)
            dl, dx = pull(g)
            return (out, dx, *dl)

        w.export("attn_vjp", attn_vjp, [["block", 1]],
                 [("x", x_s), ("g", x_s)], [*_leaf_sds(bspec), x_s, x_s])

        def ffn_fwd(*args):
            p = M.unflatten(bspec, args[:nb])
            return (M.ffn(p["ffn"], M.layer_norm(p["ln2"], args[nb])),)

        w.export("ffn_fwd", ffn_fwd, [["block", 1]], [("x", x_s)],
                 [*_leaf_sds(bspec), x_s])

        def ffn_vjp(*args):
            leaves, x, g = args[:nb], args[nb], args[nb + 1]
            def f(lv, xx):
                p = M.unflatten(bspec, lv)
                return M.ffn(p["ffn"], M.layer_norm(p["ln2"], xx))
            out, pull = jax.vjp(f, leaves, x)
            dl, dx = pull(g)
            return (out, dx, *dl)

        w.export("ffn_vjp", ffn_vjp, [["block", 1]],
                 [("x", x_s), ("g", x_s)], [*_leaf_sds(bspec), x_s, x_s])

    # ---- head + loss ----
    def head_loss_fwd(*args):
        p = M.unflatten(hspec, args[:nh])
        return M.head_loss_apply(p, args[nh], args[nh + 1], cfg)

    w.export("head_loss_fwd", head_loss_fwd, [["head", 1]],
             [("x", x_s), ("labels", lab_s)],
             [*_leaf_sds(hspec), x_s, lab_s])

    def head_loss_vjp(*args):
        leaves, x, labels = args[:nh], args[nh], args[nh + 1]
        def f(lv, xx):
            loss, _ = M.head_loss_apply(M.unflatten(hspec, lv), xx, labels, cfg)
            return loss
        _, pull = jax.vjp(f, leaves, x)
        dl, dx = pull(jnp.float32(1.0))
        return (dx, *dl)

    w.export("head_loss_vjp", head_loss_vjp, [["head", 1]],
             [("x", x_s), ("labels", lab_s)],
             [*_leaf_sds(hspec), x_s, lab_s])

    # ---- encoder side (encdec only) ----
    if cross:
        def enc_embed_fwd(*args):
            p = M.unflatten(eespec, args[:nee])
            return (M.embed_apply(p, args[nee], cfg),)

        w.export("enc_embed_fwd", enc_embed_fwd, [["enc_embed", 1]],
                 [("inputs", src_s)], [*_leaf_sds(eespec), src_s])

        def enc_embed_vjp(*args):
            leaves, inputs, g = args[:nee], args[nee], args[nee + 1]
            def f(lv):
                return M.embed_apply(M.unflatten(eespec, lv), inputs, cfg)
            _, pull = jax.vjp(f, leaves)
            (dl,) = pull(g)
            return tuple(dl)

        w.export("enc_embed_vjp", enc_embed_vjp, [["enc_embed", 1]],
                 [("inputs", src_s), ("g", mem_s)],
                 [*_leaf_sds(eespec), src_s, mem_s])

        def enc_block_fwd(*args):
            p = M.unflatten(ebspec, args[:neb])
            return (M.block_h(p, args[neb], cfg, causal=False),)

        w.export("enc_block_fwd", enc_block_fwd, [["enc_block", 1]],
                 [("x", mem_s)], [*_leaf_sds(ebspec), mem_s])

        def enc_block_vjp(*args):
            leaves, x, g = args[:neb], args[neb], args[neb + 1]
            def f(lv, xx):
                return M.block_h(M.unflatten(ebspec, lv), xx, cfg, causal=False)
            h, pull = jax.vjp(f, leaves, x)
            dl, dx = pull(g)
            return (h, dx, *dl)

        w.export("enc_block_vjp", enc_block_vjp, [["enc_block", 1]],
                 [("x", mem_s), ("g", mem_s)],
                 [*_leaf_sds(ebspec), mem_s, mem_s])

    # ---- fused quantized inference (eqs. 18-22; gamma is a runtime input) ----
    K = cfg.n_blocks
    gamma_s = _sds((), jnp.float32)

    if cross:
        Ke = cfg.n_enc_blocks
        layout = [["enc_embed", 1], ["enc_block", Ke], ["embed", 1],
                  ["block", K], ["head", 1]]

        def model_infer(*args):
            i = 0
            pee = M.unflatten(eespec, args[i:i + nee]); i += nee
            pebs = []
            for _ in range(Ke):
                pebs.append(M.unflatten(ebspec, args[i:i + neb])); i += neb
            pe = M.unflatten(espec, args[i:i + ne]); i += ne
            pbs = []
            for _ in range(K):
                pbs.append(M.unflatten(bspec, args[i:i + nb])); i += nb
            ph = M.unflatten(hspec, args[i:i + nh]); i += nh
            src, tgt, labels, gamma = (args[i], args[i + 1], args[i + 2],
                                       args[i + 3])
            params = {"enc_embed": pee, "enc_blocks": pebs, "embed": pe,
                      "blocks": pbs, "head": ph}
            return M.model_infer(params, (src, tgt), labels, gamma, cfg)

        leaf_args = (_leaf_sds(eespec)
                     + [s for _ in range(Ke) for s in _leaf_sds(ebspec)]
                     + _leaf_sds(espec)
                     + [s for _ in range(K) for s in _leaf_sds(bspec)]
                     + _leaf_sds(hspec))
        w.export("model_infer", model_infer, layout,
                 [("src", src_s), ("tgt", in_s), ("labels", lab_s),
                  ("gamma", gamma_s)],
                 [*leaf_args, src_s, in_s, lab_s, gamma_s])
    else:
        layout = [["embed", 1], ["block", K], ["head", 1]]

        def model_infer(*args):
            i = 0
            pe = M.unflatten(espec, args[i:i + ne]); i += ne
            pbs = []
            for _ in range(K):
                pbs.append(M.unflatten(bspec, args[i:i + nb])); i += nb
            ph = M.unflatten(hspec, args[i:i + nh]); i += nh
            inputs, labels, gamma = args[i], args[i + 1], args[i + 2]
            params = {"embed": pe, "blocks": pbs, "head": ph}
            return M.model_infer(params, inputs, labels, gamma, cfg)

        leaf_args = (_leaf_sds(espec)
                     + [s for _ in range(K) for s in _leaf_sds(bspec)]
                     + _leaf_sds(hspec))
        w.export("model_infer", model_infer, layout,
                 [("inputs", in_s), ("labels", lab_s), ("gamma", gamma_s)],
                 [*leaf_args, in_s, lab_s, gamma_s])

    w.finish(source_hash)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def compute_source_hash(cfg: ModelConfig) -> str:
    h = hashlib.sha256()
    h.update(json.dumps(cfg.dims_dict(), sort_keys=True).encode())
    pkg = pathlib.Path(__file__).parent
    for f in sorted(pkg.rglob("*.py")):
        h.update(f.read_bytes())
    return h.hexdigest()[:16]


def bundle_up_to_date(cfg: ModelConfig, out_dir: pathlib.Path,
                      source_hash: str) -> bool:
    mf = out_dir / cfg.name / "manifest.json"
    if not mf.exists():
        return False
    try:
        manifest = json.loads(mf.read_text())
    except json.JSONDecodeError:
        return False
    if manifest.get("source_hash") != source_hash:
        return False
    return all((out_dir / cfg.name / e["file"]).exists()
               for e in manifest["executables"].values())


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-export HLO bundles")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default=None,
                    help="export only this config (default: all)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    names = [args.config] if args.config else list(CONFIGS)
    for name in names:
        cfg = CONFIGS[name]
        src_hash = compute_source_hash(cfg)
        if not args.force and bundle_up_to_date(cfg, out_dir, src_hash):
            print(f"  [{name}] up to date")
            continue
        print(f"  [{name}] exporting...")
        export_bundle(cfg, out_dir, src_hash)
    print("artifacts OK")


if __name__ == "__main__":
    main()
