//! The title claim, demonstrated end-to-end (and the Fig.-2 contrast):
//! exact bit-level reconstruction with quantization + side info, vs the
//! drifting float inversion of eq. 16.
//!
//! ```bash
//! cargo run --release --example reversibility_check
//! ```

use bdia::coordinator::{GammaPlan, Stack, StackKind, StackState};
use bdia::model::ParamStore;
use bdia::quant;
use bdia::runtime::Runtime;
use bdia::tensor::{Rng, Tensor};
use anyhow::Result;
use std::path::Path;

fn main() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"), "gpt_tiny")?;
    let dims = rt.manifest.dims.clone();
    println!(
        "BDIA-GPT2 config: K={} blocks, batch={}, T={}, D={}, grid 2^-{}",
        dims.n_blocks, dims.batch, dims.seq, dims.d_model, dims.lbits
    );
    let params = ParamStore::init(&rt.manifest, 0);
    let stack = Stack::new(&rt, StackKind::Main)?;
    let mut rng = Rng::new(123);
    let x0 = Tensor::normal(&[dims.batch, dims.seq, dims.d_model], 1.0, &mut rng);
    let plan = GammaPlan::draw(&mut rng, stack.n_blocks, dims.batch, 0.5);

    // ---- float path: forward eq. 10, invert eq. 16 (drifts, Fig. 2) ----
    let StackState::Full { xs } = stack.forward_float(&params, x0.clone(), None, &plan)?
    else {
        unreachable!()
    };
    println!("\nfloat inversion (eq. 16) walking top -> bottom:");
    let k_total = stack.n_blocks;
    let mut x_next = xs[k_total].clone();
    let mut x_cur = xs[k_total - 1].clone();
    for k in (1..k_total).rev() {
        let h = stack.debug_call_fwd(&params, k, &x_cur, None)?;
        let rec = quant::bdia_invert_float(&x_next, &x_cur, &h, &plan.gammas[k])?;
        println!(
            "  x_{:<2} max |err| = {:.3e}",
            k - 1,
            rec.max_abs_diff(&xs[k - 1])?
        );
        x_next = x_cur;
        x_cur = rec;
    }

    // ---- quantized path: forward eqs. 18-21, reconstruct eq. 24 ----
    let state = stack.forward_quant(&params, x0, None, &plan)?;
    let stored = state.stored_bytes();
    let rec = stack.reconstruct_all(&params, &state, None, &plan)?;
    // oracle for comparison: record-all quantized forward
    let mut oracle = {
        let mut x = rec[0].clone();
        quant::quantize_activation(&mut x, stack.fixed);
        vec![x]
    };
    let h0 = stack.debug_call_fwd(&params, 0, &oracle[0], None)?;
    oracle.push(quant::first_step_quant(&oracle[0], &h0, stack.fixed)?);
    for k in 1..k_total {
        let h = stack.debug_call_fwd(&params, k, &oracle[k], None)?;
        let signs = plan.signs(k)?;
        let (nx, _) =
            quant::bdia_forward_quant(&oracle[k - 1], &oracle[k], &h, &signs, stack.fixed)?;
        oracle.push(nx);
    }
    println!("\nquantized reconstruction (eq. 24) with 1-bit side info:");
    let mut max_err = 0f32;
    for k in (0..k_total).rev() {
        let err = oracle[k].max_abs_diff(&rec[k])?;
        max_err = max_err.max(err);
        println!("  x_{k:<2} max |err| = {err:.1}  (bit-exact)");
    }
    assert_eq!(max_err, 0.0);
    let store_all: usize = oracle.iter().map(Tensor::nbytes).sum();
    println!(
        "\nstored for backward: {} vs store-all {} ({}x less); drift: 0 bits",
        bdia::metrics::fmt_bytes(stored),
        bdia::metrics::fmt_bytes(store_all),
        store_all / stored.max(1)
    );
    Ok(())
}
