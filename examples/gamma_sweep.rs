//! Fig.-1 style inference-gamma sweep on an (optionally briefly trained)
//! ViT: evaluates the family of ODE solvers `gamma in [-0.5, 0.5]` through
//! the fused `model_infer` executable (gamma is a runtime input — one AOT
//! artifact serves the whole sweep).
//!
//! ```bash
//! cargo run --release --example gamma_sweep -- [train_steps]
//! ```

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use anyhow::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(30);
    for (label, mode) in [
        ("ViT(vanilla)", TrainMode::Vanilla),
        ("BDIA-ViT", TrainMode::BdiaReversible),
    ] {
        let cfg = TrainConfig {
            model: "vit_s10".into(),
            mode,
            dataset: "synth_cifar10".into(),
            steps,
            eval_every: 0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg.clone())?;
        let ds = dataset_for(&tr.rt, &cfg)?;
        for step in 0..steps {
            let b = ds.train_batch(step);
            tr.train_step(&b)?;
        }
        println!("\n{label} after {steps} steps — val acc by inference gamma:");
        for g in [-0.5f32, -0.4, -0.3, -0.2, -0.1, 0.0, 0.1, 0.2, 0.3, 0.4, 0.5] {
            let (_, acc) = tr.evaluate(ds.as_ref(), 2, g)?;
            let bar = "#".repeat((acc * 60.0) as usize);
            println!("  gamma {g:>4.1}  acc {acc:.3}  {bar}");
        }
    }
    Ok(())
}
