//! Fig.-5 style overfitting study: GPT2 vs BDIA-GPT2 on a deliberately tiny
//! training pool (48 windows of the synthetic corpus).  Watch the
//! generalization gap: BDIA trains slower but holds the lower val loss.
//!
//! ```bash
//! cargo run --release --example lm_overfit -- [steps]
//! ```

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use anyhow::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(120);
    let mut results = Vec::new();
    for (label, mode) in [
        ("GPT2", TrainMode::Vanilla),
        ("BDIA-GPT2", TrainMode::BdiaReversible),
    ] {
        let cfg = TrainConfig {
            model: "gpt_tiny".into(),
            mode,
            dataset: "tiny_corpus".into(),
            steps,
            train_examples: 48, // ~0.05%-of-corpus analogue: tiny pool
            lr: 3e-4,
            eval_every: (steps / 6).max(1),
            eval_batches: 2,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg.clone())?;
        let ds = dataset_for(&tr.rt, &cfg)?;
        println!("\n{label}: 12 blocks, {} params, 48-window pool", tr.n_params());
        let mut last_train = f32::NAN;
        for step in 0..steps {
            let b = ds.train_batch(step);
            let s = tr.train_step(&b)?;
            last_train = s.loss;
            if step % cfg.eval_every == cfg.eval_every - 1 {
                let (vl, _) = tr.evaluate(ds.as_ref(), 2, 0.0)?;
                println!(
                    "  step {:>4}  train_loss {:.4}  val_loss {:.4}  gap {:+.4}",
                    step,
                    s.loss,
                    vl,
                    vl - s.loss
                );
            }
        }
        let (vl, _) = tr.evaluate(ds.as_ref(), 4, 0.0)?;
        results.push((label, last_train, vl));
    }
    println!("\nsummary (paper Fig. 5 shape: BDIA ends with lower val loss):");
    for (label, tr_l, vl) in results {
        println!(
            "  {label:<10} final train {tr_l:.4}  val {vl:.4}  gap {:+.4}",
            vl - tr_l
        );
    }
    Ok(())
}
