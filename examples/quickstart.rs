//! Quickstart: train a BDIA-ViT for a handful of steps with exact bit-level
//! reversible (online) back-propagation, and show the memory story.
//!
//! Runs on the pure-Rust native backend — no artifacts, no XLA:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! (Pass `backend=pjrt` semantics via TrainConfig when built with the
//! `pjrt` feature and `make artifacts` has been run.)

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use bdia::metrics::fmt_bytes;
use bdia::metrics::memory::MemoryModel;
use anyhow::Result;

fn main() -> Result<()> {
    let cfg = TrainConfig {
        model: "vit_s10".into(),
        mode: TrainMode::BdiaReversible, // the paper's system
        gamma_mag: 0.5,                  // gamma ~ Uniform{+0.5, -0.5}
        dataset: "synth_cifar10".into(),
        steps: 20,
        eval_every: 10,
        eval_batches: 2,
        log_every: 1,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg.clone())?;
    println!(
        "BDIA-ViT: {} params, K={} blocks, batch={} [{} backend]",
        trainer.n_params(),
        trainer.rt.manifest.dims.n_blocks,
        trainer.rt.manifest.dims.batch,
        trainer.rt.backend.name()
    );

    // what reversibility buys (the paper's Table-1 comparison, analytically)
    for mode in [TrainMode::Vanilla, TrainMode::BdiaReversible] {
        let mm = MemoryModel::new(
            mode,
            trainer.family,
            &trainer.rt.manifest.dims,
            trainer.n_params() * 4,
        );
        println!(
            "  peak training memory [{:>8}]: {:>10}  (activations {}, side info {})",
            mode.name(),
            fmt_bytes(mm.peak_total()),
            fmt_bytes(mm.stored_activations()),
            fmt_bytes(mm.side_info()),
        );
    }

    let ds = dataset_for(&trainer.rt, &cfg)?;
    for step in 0..cfg.steps {
        let batch = ds.train_batch(step);
        let stats = trainer.train_step(&batch)?;
        println!(
            "step {:>3}  loss {:.4}  acc {:.3}  |g| {:.3}  stored acts {}",
            step,
            stats.loss,
            stats.acc,
            stats.grad_norm,
            fmt_bytes(stats.stored_activation_bytes)
        );
    }
    let (vl, va) = trainer.evaluate(ds.as_ref(), 2, 0.0)?;
    println!("validation (gamma=0, standard architecture): loss {vl:.4} acc {va:.3}");
    Ok(())
}
