//! End-to-end driver (the repo's required full-system validation):
//! train the largest-feasible GPT-style LM on this CPU testbed — 8 blocks,
//! d_model 256, seq 128 (~6.8M params; the paper-scale substitution is
//! recorded in DESIGN.md §5) — for a few hundred steps with exact bit-level
//! reversible online backprop on a real synthetic corpus, logging the loss
//! curve.  All layers compose: Pallas kernels -> JAX AOT HLO -> PJRT runtime
//! -> Rust BDIA coordinator.  The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [steps]
//! ```

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::data::prefetch::Prefetcher;
use bdia::experiments::dataset_for;
use bdia::metrics::{fmt_bytes, Record, TrainLog};
use bdia::metrics::memory::MemoryModel;
use anyhow::Result;
use std::sync::Arc;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(250);
    let cfg = TrainConfig {
        model: "gpt_e2e".into(),
        mode: TrainMode::BdiaReversible,
        gamma_mag: 0.5,
        dataset: "tiny_corpus".into(),
        steps,
        train_examples: 4096,
        val_examples: 256,
        lr: 3e-4,
        eval_every: 50,
        eval_batches: 2,
        log_every: 5,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(cfg.clone())?;
    let dims = tr.rt.manifest.dims.clone();
    println!(
        "e2e: gpt_e2e — {} params, K={} blocks, d={}, T={}, batch={}",
        tr.n_params(),
        dims.n_blocks,
        dims.d_model,
        dims.seq,
        dims.batch
    );
    let mm = MemoryModel::new(cfg.mode, tr.family, &dims, tr.n_params() * 4);
    let mv = MemoryModel::new(TrainMode::Vanilla, tr.family, &dims, tr.n_params() * 4);
    println!(
        "peak training memory: reversible {} vs store-all {}",
        fmt_bytes(mm.peak_total()),
        fmt_bytes(mv.peak_total())
    );

    let ds = dataset_for(&tr.rt, &cfg)?;
    let ds_arc: Arc<dyn bdia::data::Dataset> = Arc::from(ds);
    // async data pipeline: generation overlaps the training step
    let mut prefetch = Prefetcher::new(ds_arc.clone(), steps, 4);

    let mut log = TrainLog::new("e2e_gpt");
    let t_start = std::time::Instant::now();
    for step in 0..steps {
        let batch = prefetch.next_batch().expect("prefetcher");
        let t0 = std::time::Instant::now();
        let stats = tr.train_step(&batch)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let eval_due = step % cfg.eval_every == cfg.eval_every - 1 || step + 1 == steps;
        let (vl, va) = if eval_due {
            let (l, a) = tr.evaluate(ds_arc.as_ref(), cfg.eval_batches, 0.0)?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };
        if step % cfg.log_every == 0 || eval_due {
            println!(
                "step {:>4}  train_loss {:.4}  acc {:.3}  {}  {:.0} ms/step{}",
                step,
                stats.loss,
                stats.acc,
                fmt_bytes(stats.stored_activation_bytes),
                ms,
                match (vl, va) {
                    (Some(l), Some(a)) => format!("  | val_loss {l:.4} val_acc {a:.3}"),
                    _ => String::new(),
                }
            );
        }
        log.push(Record {
            step,
            train_loss: stats.loss,
            train_acc: stats.acc,
            val_loss: vl,
            val_acc: va,
            grad_norm: stats.grad_norm,
            ms_per_step: ms,
        });
    }
    let total = t_start.elapsed().as_secs_f64();
    let tokens = steps * dims.batch * dims.seq;
    println!(
        "\ndone: {steps} steps in {total:.0}s — {:.0} tokens/s training throughput",
        tokens as f64 / total
    );
    log.write_csv(std::path::Path::new("results/e2e_gpt.csv"))?;
    println!("loss curve written to results/e2e_gpt.csv");
    Ok(())
}
