//! Encoder-decoder BDIA training on the synthetic transduction grammar
//! (the paper's §5.2 en→fr workload stand-in): exercises cross-attention,
//! dmem gradient routing, and BDIA reversibility in BOTH stacks.
//!
//! ```bash
//! cargo run --release --example translation -- [steps]
//! ```

use bdia::config::{TrainConfig, TrainMode};
use bdia::coordinator::Trainer;
use bdia::experiments::dataset_for;
use anyhow::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps"))
        .unwrap_or(60);
    for (label, mode) in [
        ("transformer", TrainMode::Vanilla),
        ("BDIA-transformer", TrainMode::BdiaReversible),
    ] {
        let cfg = TrainConfig {
            model: "encdec_mt".into(),
            mode,
            dataset: "synth_translation".into(),
            steps,
            train_examples: 512,
            lr: 3e-4,
            eval_every: steps / 3,
            eval_batches: 2,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(cfg.clone())?;
        let ds = dataset_for(&tr.rt, &cfg)?;
        println!(
            "\n{label}: 6+6 enc/dec blocks, {} params",
            tr.n_params()
        );
        for step in 0..steps {
            let b = ds.train_batch(step);
            let s = tr.train_step(&b)?;
            if step % (steps / 6).max(1) == 0 {
                println!(
                    "  step {:>3}  train_loss {:.4}  token acc {:.3}",
                    step, s.loss, s.acc
                );
            }
        }
        let (vl, va) = tr.evaluate(ds.as_ref(), 4, 0.0)?;
        println!("  final: val_loss {vl:.4}  val token acc {va:.3}");
    }
    Ok(())
}
